//! The asynchronous submission pipeline: a bounded MPSC queue with
//! blocking backpressure, a dedicated dispatcher thread that forms arrival
//! batches inside a time/count-bounded window, and per-request completion
//! tickets — so arrival batches *overlap* in-flight sharded tails instead
//! of serializing behind them.
//!
//! Why this layer exists: the paper's saturation result means a handful of
//! workers already extract the chip's bandwidth, so a serving layer wins
//! or loses on *keeping the pool busy*, not on the kernel. The synchronous
//! [`DotService`] API blocks the submitting thread on every batch and runs
//! sharded tails one after another; under open-loop arrivals the service
//! therefore pays queueing it could have overlapped. The pipeline here
//! decouples the three stages:
//!
//! ```text
//! submit() ──► bounded queue ──► dispatcher ──► pool worker FIFOs
//!  (blocks        (depth-         (drains a       (fused groups and
//!   past the       bounded         batching        shard partitions
//!   depth =        memory)         window,         pipeline back-to-
//!   backpressure)                  posts async)    back; no idle gaps)
//! ```
//!
//! **Determinism contract.** At a fixed thread count every request's
//! result is bit-identical to the synchronous path regardless of arrival
//! interleaving — the dispatcher may *group* requests differently run to
//! run, but grouping only decides where work executes, never what it
//! computes: fused requests run the service's serial kernel over the whole
//! input, sharded requests run the pool-width partition + deterministic
//! compensated tree reduction, exactly as `submit`/`submit_batch` do.
//! Only completion *order* may differ (property-pinned in
//! `tests/properties.rs`).
//!
//! **Resource bounds.** Producer memory is bounded by the queue depth
//! (`submit` blocks when full); dispatcher memory is bounded by
//! [`MAX_INFLIGHT_DISPATCHES`] × the batching cap (past that, the
//! dispatcher retires the oldest dispatch before draining more). Tickets
//! are `Arc`-owned: dropping a [`ResponseHandle`] without waiting leaks
//! nothing, and dropping the service closes the queue, drains everything
//! already accepted, completes every ticket and joins the dispatcher.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::arena::AlignedVec;
use crate::runtime::backend::{BackendError, KernelInput};
use crate::runtime::parallel::{
    compensated_tree_reduce, PendingDispatch, ThreadPool, CACHELINE_F64,
};

use super::faults::{FaultInjector, FaultSite};
use super::scheduler::ExecPath;
use super::store::{
    CacheStats, CachedResult, OperandStore, RegisterOutcome, ResultCache, StoreError, StoreStats,
    CACHE_DEFAULT_ENTRIES, STORE_DEFAULT_CAPACITY_BYTES,
};
use super::{DotService, ServeConfig, ServeResponse, SharedInput};

/// Dispatcher-side cap on concurrently in-flight pool dispatches: past
/// this the dispatcher retires the oldest dispatch before draining more
/// arrivals, so total buffered work is bounded by
/// `queue_depth + MAX_INFLIGHT_DISPATCHES * batch_max` requests.
pub const MAX_INFLIGHT_DISPATCHES: usize = 8;

/// How long the dispatcher waits on an empty queue before re-checking
/// whether the oldest in-flight dispatch finished. Bounds the retire lag
/// of a completed dispatch (and therefore ticket-resolution promptness)
/// at light load without busy-spinning the dispatcher thread.
const RETIRE_POLL: Duration = Duration::from_micros(50);

/// Tuning for the asynchronous pipeline ([`AsyncDotService::new`]).
#[derive(Clone, Copy, Debug)]
pub struct AsyncOptions {
    /// Submission-queue depth (>= 1). `submit` blocks while the queue
    /// holds this many requests — the backpressure bound.
    pub queue_depth: usize,
    /// How long the dispatcher keeps a non-empty arrival batch open for
    /// more requests. Zero means "drain whatever has already arrived and
    /// dispatch immediately".
    pub batch_window: Duration,
    /// Count bound on one arrival batch (>= 1).
    pub batch_max: usize,
    /// `true` (the default): post dispatches without waiting, so arrival
    /// batches overlap in-flight work. `false`: retire every dispatch
    /// before draining the next batch — the pipelined-but-serialized
    /// baseline `serve-bench` reports side by side with the async rows.
    pub overlap: bool,
    /// Default per-request deadline, measured from the request's arrival
    /// instant. A request still queued when its deadline expires is *shed*:
    /// resolved with the typed [`BackendError::DeadlineExceeded`] error by
    /// the dispatcher before any compute. `None` (the default) disables
    /// shedding; per-request overrides go through
    /// [`AsyncDotService::submit_with_deadline`].
    pub deadline: Option<Duration>,
}

impl Default for AsyncOptions {
    fn default() -> Self {
        Self {
            queue_depth: 256,
            batch_window: Duration::from_micros(100),
            batch_max: 64,
            overlap: true,
            deadline: None,
        }
    }
}

/// What a queue pop observed.
enum Pop<T> {
    Item(T),
    Empty,
    Closed,
}

/// Why a non-blocking push was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TryPush {
    /// The queue is at its configured depth.
    Full,
    /// The queue is closed (service shutting down).
    Closed,
}

/// Outcome of a non-blocking submission
/// ([`AsyncDotService::try_submit`]).
#[derive(Debug)]
pub enum TrySubmit {
    /// The request was admitted; resolve it through the handle as usual.
    Accepted(ResponseHandle),
    /// The queue was at depth: nothing was enqueued and the caller may
    /// retry. The wire server turns this into the documented BUSY error
    /// frame (`docs/PROTOCOL.md` §5) instead of blocking the connection.
    Busy,
    /// The request's tenant was at its per-tenant queue quota: nothing was
    /// enqueued, and — unlike [`TrySubmit::Busy`] — retrying immediately
    /// cannot help until some of this tenant's queued work drains. The
    /// wire server turns this into the typed QUOTA error frame
    /// (`docs/PROTOCOL.md` §4.11), distinct from BUSY so clients can tell
    /// "the service is overloaded" from "I am over my share".
    Quota,
}

/// One tenant class in a [`QosPolicy`]: a display name, a weighted-fair
/// share, and an optional per-tenant queue quota. Tenant ids are indices
/// into [`QosPolicy::classes`]; ids past the end of the policy fall back
/// to weight 1 and no quota.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantClass {
    /// Display name, used in bench artifacts and diagnostics.
    pub name: String,
    /// Deficit-round-robin weight (clamped to >= 1 at construction): a
    /// continuously backlogged tenant's share of dispatched requests
    /// converges to `weight / Σ weights` (property-pinned).
    pub weight: u32,
    /// Maximum requests this tenant may hold admitted-but-undispatched
    /// (queue + dispatcher backlog). `None` means no per-tenant bound —
    /// only the whole-queue depth applies.
    pub quota: Option<usize>,
}

/// Multi-tenant QoS policy: the tenant classes plus the pure
/// deficit-round-robin selection core the dispatcher schedules with.
///
/// The policy decides *where and when* a request runs, never *what* it
/// computes: batch composition downstream of selection is still a plan
/// function of lengths only (`BatchScheduler::plan_lens`), so results at
/// fixed `T` are bit-identical across any priority interleaving
/// (property-pinned in `tests/properties.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QosPolicy {
    classes: Vec<TenantClass>,
}

impl QosPolicy {
    /// Build a policy from explicit classes. Weights are clamped to >= 1
    /// so a zero-weight class cannot be starved into deadlock.
    pub fn new(mut classes: Vec<TenantClass>) -> Self {
        for c in &mut classes {
            c.weight = c.weight.max(1);
        }
        Self { classes }
    }

    /// Parse a `--tenants` spec. Two forms:
    ///
    /// * `name:weight[:quota],...` — e.g. `a:3,b:1` or `a:3:16,b:1:8`;
    /// * a bare weight list `w0:w1[:w2...]` — e.g. `3:1` — when the single
    ///   comma-free entry is all-numeric with >= 2 fields; tenants are
    ///   auto-named `t0`, `t1`, ….
    pub fn parse(spec: &str) -> Result<Self, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty tenant spec".to_string());
        }
        let entries: Vec<&str> = spec.split(',').collect();
        if entries.len() == 1 {
            let fields: Vec<&str> = entries[0].split(':').collect();
            if fields.len() >= 2 && fields.iter().all(|f| f.trim().parse::<u32>().is_ok()) {
                let classes = fields
                    .iter()
                    .enumerate()
                    .map(|(i, f)| TenantClass {
                        name: format!("t{i}"),
                        weight: f.trim().parse::<u32>().unwrap(),
                        quota: None,
                    })
                    .collect();
                return Ok(Self::new(classes));
            }
        }
        let mut classes = Vec::new();
        for entry in &entries {
            let fields: Vec<&str> = entry.split(':').collect();
            let (name, weight, quota) = match fields.as_slice() {
                [name, w] => (name.trim(), w.trim(), None),
                [name, w, q] => (name.trim(), w.trim(), Some(q.trim())),
                _ => {
                    return Err(format!(
                        "tenant entry '{entry}' is not name:weight[:quota]"
                    ))
                }
            };
            if name.is_empty() {
                return Err(format!("tenant entry '{entry}' has an empty name"));
            }
            let weight: u32 = weight
                .parse()
                .map_err(|_| format!("tenant '{name}': weight '{weight}' is not a u32"))?;
            let quota = match quota {
                Some(q) => Some(
                    q.parse::<usize>()
                        .map_err(|_| format!("tenant '{name}': quota '{q}' is not a usize"))?,
                ),
                None => None,
            };
            classes.push(TenantClass {
                name: name.to_string(),
                weight,
                quota,
            });
        }
        Ok(Self::new(classes))
    }

    /// The configured classes, in tenant-id order.
    pub fn classes(&self) -> &[TenantClass] {
        &self.classes
    }

    /// A tenant's weight; ids outside the policy default to 1.
    pub fn weight(&self, tenant: u32) -> u32 {
        self.classes
            .get(tenant as usize)
            .map_or(1, |c| c.weight.max(1))
    }

    /// A tenant's quota; ids outside the policy (or classes with no
    /// configured quota) are unbounded.
    pub fn quota(&self, tenant: u32) -> usize {
        self.classes
            .get(tenant as usize)
            .and_then(|c| c.quota)
            .unwrap_or(usize::MAX)
    }

    /// A tenant's display name; ids outside the policy render as `t{id}`.
    pub fn name(&self, tenant: u32) -> String {
        self.classes
            .get(tenant as usize)
            .map_or_else(|| format!("t{tenant}"), |c| c.name.clone())
    }

    /// Fill every unset quota with a weight-proportional share of the
    /// queue depth (minimum 1) — the serve-bench default, sized so a
    /// saturating tenant hits its quota well before it can occupy the
    /// whole queue.
    pub fn with_default_quotas(mut self, queue_depth: usize) -> Self {
        let total: u64 = self
            .classes
            .iter()
            .map(|c| u64::from(c.weight.max(1)))
            .sum::<u64>()
            .max(1);
        for c in &mut self.classes {
            if c.quota.is_none() {
                let share = (queue_depth as u64 * u64::from(c.weight.max(1)) / total).max(1);
                c.quota = Some(share as usize);
            }
        }
        self
    }

    /// The deficit-round-robin core: given the carried-over deficit
    /// counters and each backlogged tenant's pending depth, return the
    /// tenant drain order for one batch of at most `batch_max` requests.
    ///
    /// Each round credits every still-backlogged tenant its weight, then
    /// drains `min(deficit, pending, room)`; a tenant whose lane empties
    /// forfeits its remaining deficit (standard DRR — prevents an idle
    /// tenant from hoarding credit), while a tenant cut off by `batch_max`
    /// keeps it (the carryover that makes long-run shares converge to the
    /// weights). Pure — operates only on the supplied state — so the
    /// fairness invariant is property-tested without a running service.
    pub fn drr_select(
        &self,
        deficits: &mut BTreeMap<u32, u64>,
        pending: &BTreeMap<u32, usize>,
        batch_max: usize,
    ) -> Vec<u32> {
        let mut remaining: BTreeMap<u32, usize> = pending
            .iter()
            .filter(|&(_, &n)| n > 0)
            .map(|(&t, &n)| (t, n))
            .collect();
        let mut order = Vec::new();
        while order.len() < batch_max && !remaining.is_empty() {
            let ids: Vec<u32> = remaining.keys().copied().collect();
            for tenant in ids {
                if order.len() >= batch_max {
                    break;
                }
                let mut deficit =
                    deficits.get(&tenant).copied().unwrap_or(0) + u64::from(self.weight(tenant));
                let avail = remaining[&tenant] as u64;
                let room = (batch_max - order.len()) as u64;
                let take = deficit.min(avail).min(room);
                deficit -= take;
                for _ in 0..take {
                    order.push(tenant);
                }
                if take == avail {
                    remaining.remove(&tenant);
                    deficits.insert(tenant, 0);
                } else {
                    deficits.insert(tenant, deficit);
                    if take > 0 {
                        *remaining.get_mut(&tenant).expect("tenant still backlogged") -=
                            take as usize;
                    }
                }
            }
        }
        order
    }
}

/// Per-tenant accounting snapshot row
/// ([`AsyncDotService::tenant_stats`]). All counters are monotonic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    /// Tenant id (index into the policy's classes).
    pub tenant: u32,
    /// Requests admitted past the quota check into the pipeline.
    pub admitted: u64,
    /// Admitted requests whose ticket has resolved — success, typed shed
    /// error, or shutdown drain. At quiescence `completed == admitted`.
    pub completed: u64,
    /// Requests shed at admission because the tenant was at quota. Never
    /// entered the pipeline; disjoint from `admitted`.
    pub quota_shed: u64,
    /// Admitted requests shed in-queue on deadline expiry (a subset of
    /// `completed`, mirroring the global counter's semantics).
    pub deadline_shed: u64,
    /// Handle-submitted requests answered from the result cache without
    /// entering the queue. Counted as both admitted and completed (the
    /// conservation invariant `completed == admitted` at quiescence is
    /// preserved), but never against quota occupancy — a hit consumes no
    /// queue slot and no compute.
    pub cache_hits: u64,
}

#[derive(Clone, Copy, Debug, Default)]
struct TenantEntry {
    /// Currently admitted-but-undispatched requests — the value the quota
    /// check gates on.
    occupancy: u64,
    admitted: u64,
    completed: u64,
    quota_shed: u64,
    deadline_shed: u64,
    cache_hits: u64,
}

/// Shared per-tenant quota enforcement + accounting. One mutex guards the
/// whole map: admission takes it once per request, which is noise next to
/// the queue mutex the same call already takes.
struct TenantTable {
    policy: Option<QosPolicy>,
    entries: Mutex<BTreeMap<u32, TenantEntry>>,
}

impl TenantTable {
    fn new(policy: Option<QosPolicy>) -> Self {
        Self {
            policy,
            entries: Mutex::new(BTreeMap::new()),
        }
    }

    /// Poison-tolerant map access (same policy as the queue mutex).
    fn lock(&self) -> MutexGuard<'_, BTreeMap<u32, TenantEntry>> {
        self.entries
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Quota-check-and-admit in one critical section, so two racing
    /// submitters cannot both slip under the quota. `true` counts the
    /// request as admitted (occupancy +1); `false` counts it as
    /// quota-shed, exactly once — the shed request never appears in any
    /// other counter.
    fn admit(&self, tenant: u32) -> bool {
        let quota = self
            .policy
            .as_ref()
            .map_or(usize::MAX, |p| p.quota(tenant));
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        if e.occupancy as usize >= quota {
            e.quota_shed += 1;
            return false;
        }
        e.occupancy += 1;
        e.admitted += 1;
        true
    }

    /// Record an injected quota reject (the `QuotaAdmissionReject` fault
    /// site): same observable accounting as a real quota shed.
    fn force_quota_shed(&self, tenant: u32) {
        self.lock().entry(tenant).or_default().quota_shed += 1;
    }

    /// Roll back an admission whose queue push was refused (full/closed),
    /// so a rejected request is not double-counted as admitted.
    fn unadmit(&self, tenant: u32) {
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        e.occupancy = e.occupancy.saturating_sub(1);
        e.admitted = e.admitted.saturating_sub(1);
    }

    /// The request left the queue/backlog for dispatch: quota occupancy
    /// drops; completion is recorded separately at retire.
    fn release(&self, tenant: u32) {
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        e.occupancy = e.occupancy.saturating_sub(1);
    }

    /// An already-released request shed on deadline expiry: counts as both
    /// deadline-shed and completed (its ticket resolved with the typed
    /// error).
    fn shed_deadline(&self, tenant: u32) {
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        e.deadline_shed += 1;
        e.completed += 1;
    }

    /// A dispatched request's ticket resolved (success or worker error).
    fn complete(&self, tenant: u32) {
        self.lock().entry(tenant).or_default().completed += 1;
    }

    /// A handle-submit answered from the result cache: admitted and
    /// completed in the same instant, without ever holding quota occupancy
    /// — the hit consumes no queue slot, so gating it on quota would shed
    /// the cheapest requests the tenant has.
    fn cache_hit(&self, tenant: u32) {
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        e.admitted += 1;
        e.completed += 1;
        e.cache_hits += 1;
    }

    /// A request drained straight out of the queue at shutdown: releases
    /// its occupancy and counts the (error) completion in one step.
    fn drain_complete(&self, tenant: u32) {
        let mut entries = self.lock();
        let e = entries.entry(tenant).or_default();
        e.occupancy = e.occupancy.saturating_sub(1);
        e.completed += 1;
    }

    fn total_quota_shed(&self) -> u64 {
        self.lock().values().map(|e| e.quota_shed).sum()
    }

    fn snapshot(&self) -> Vec<TenantStats> {
        self.lock()
            .iter()
            .map(|(&tenant, e)| TenantStats {
                tenant,
                admitted: e.admitted,
                completed: e.completed,
                quota_shed: e.quota_shed,
                deadline_shed: e.deadline_shed,
                cache_hits: e.cache_hits,
            })
            .collect()
    }
}

/// Depth-bounded MPSC queue with blocking backpressure: `push` blocks
/// while the queue is full, `close` wakes everyone and lets already-queued
/// items drain. Built on a mutex + two condvars so the depth bound is
/// *exact* (observable via [`BoundedQueue::max_depth_seen`]) — the
/// property tests pin it.
struct BoundedQueue<T> {
    depth: usize,
    shared: Mutex<QueueShared<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct QueueShared<T> {
    items: VecDeque<T>,
    closed: bool,
    enqueued: u64,
    max_depth_seen: usize,
}

impl<T> BoundedQueue<T> {
    fn new(depth: usize) -> Self {
        Self {
            depth: depth.max(1),
            shared: Mutex::new(QueueShared {
                items: VecDeque::new(),
                closed: false,
                enqueued: 0,
                max_depth_seen: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Poison-tolerant shared-state access — the one lock helper every
    /// queue method routes through. A thread that panicked while holding
    /// the queue mutex (a dispatcher bug, an injected fault) leaves the
    /// `VecDeque` and counters structurally intact, so submitters and the
    /// dispatcher keep operating on it instead of wedging behind the
    /// poison. Ticket slots use the same policy ([`Ticket::lock_slot`]).
    fn lock_shared(&self) -> MutexGuard<'_, QueueShared<T>> {
        self.shared
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Poison-tolerant condvar wait (same rationale as [`Self::lock_shared`]).
    fn wait_on<'a>(
        cv: &Condvar,
        guard: MutexGuard<'a, QueueShared<T>>,
    ) -> MutexGuard<'a, QueueShared<T>> {
        cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Blocking bounded push. Returns the item back when the queue is
    /// closed (shutdown raced the submit).
    fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.lock_shared();
        loop {
            if s.closed {
                return Err(item);
            }
            if s.items.len() < self.depth {
                s.items.push_back(item);
                s.enqueued += 1;
                if s.items.len() > s.max_depth_seen {
                    s.max_depth_seen = s.items.len();
                }
                self.not_empty.notify_one();
                return Ok(());
            }
            s = Self::wait_on(&self.not_full, s);
        }
    }

    /// Non-blocking bounded push: `Ok(())` when admitted, `Err` returning
    /// the item when the queue is at depth ([`TryPush::Full`]) or closed
    /// ([`TryPush::Closed`]). The wire front-end uses this so a full queue
    /// becomes a BUSY response on the socket instead of a blocked
    /// connection thread.
    fn try_push(&self, item: T) -> Result<(), (T, TryPush)> {
        let mut s = self.lock_shared();
        if s.closed {
            return Err((item, TryPush::Closed));
        }
        if s.items.len() >= self.depth {
            return Err((item, TryPush::Full));
        }
        s.items.push_back(item);
        s.enqueued += 1;
        if s.items.len() > s.max_depth_seen {
            s.max_depth_seen = s.items.len();
        }
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available or the queue is closed *and*
    /// drained (closing still delivers everything already accepted).
    fn pop_wait(&self) -> Option<T> {
        let mut s = self.lock_shared();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = Self::wait_on(&self.not_empty, s);
        }
    }

    /// Non-blocking pop.
    fn try_pop(&self) -> Pop<T> {
        let mut s = self.lock_shared();
        match s.items.pop_front() {
            Some(item) => {
                self.not_full.notify_one();
                Pop::Item(item)
            }
            None if s.closed => Pop::Closed,
            None => Pop::Empty,
        }
    }

    /// Pop with a deadline: waits at most `timeout` for an item.
    fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut s = self.lock_shared();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if s.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::Empty;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            s = guard;
        }
    }

    fn close(&self) {
        let mut s = self.lock_shared();
        s.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    fn counters(&self) -> (u64, usize) {
        let s = self.lock_shared();
        (s.enqueued, s.max_depth_seen)
    }
}

/// One request's completion slot. Completed exactly once by the
/// dispatcher; read by whoever holds the [`ResponseHandle`].
struct Ticket {
    slot: Mutex<TicketSlot>,
    ready: Condvar,
}

enum TicketSlot {
    Pending,
    /// Result plus the measured arrival→completion latency in ns.
    Ready(Result<ServeResponse, BackendError>, f64),
    /// `wait` already consumed the result.
    Claimed,
}

impl Ticket {
    fn new() -> Self {
        Self {
            slot: Mutex::new(TicketSlot::Pending),
            ready: Condvar::new(),
        }
    }

    /// Poison-tolerant slot access: a panic anywhere near a ticket must
    /// degrade to an error result, never to a hung or aborting waiter.
    fn lock_slot(&self) -> std::sync::MutexGuard<'_, TicketSlot> {
        self.slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Resolve the ticket. Panics if it was already resolved — tickets
    /// complete exactly once by construction, and this assert keeps it
    /// that way.
    fn complete(&self, result: Result<ServeResponse, BackendError>, latency_ns: f64) {
        let mut slot = self.lock_slot();
        assert!(matches!(*slot, TicketSlot::Pending), "ticket resolved twice");
        *slot = TicketSlot::Ready(result, latency_ns);
        self.ready.notify_all();
    }
}

/// The per-request completion handle the async pipeline hands back at
/// submission. `wait` blocks until the dispatcher resolves the ticket;
/// `try_wait` polls without blocking. Dropping an unresolved handle is
/// safe: the ticket state is `Arc`-shared, the request still executes,
/// and everything is freed once both sides let go.
pub struct ResponseHandle {
    ticket: Arc<Ticket>,
}

impl ResponseHandle {
    /// Block until the request completes and take the response.
    pub fn wait(self) -> Result<ServeResponse, BackendError> {
        self.wait_timed().map(|(r, _)| r)
    }

    /// [`Self::wait`], also returning the measured arrival→completion
    /// latency in nanoseconds (what the open-loop load generator records —
    /// queueing, backpressure and service time included).
    pub fn wait_timed(self) -> Result<(ServeResponse, f64), BackendError> {
        let mut slot = self.ticket.lock_slot();
        loop {
            match std::mem::replace(&mut *slot, TicketSlot::Claimed) {
                TicketSlot::Ready(result, latency_ns) => {
                    return result.map(|r| (r, latency_ns));
                }
                TicketSlot::Claimed => unreachable!("wait consumes the handle"),
                TicketSlot::Pending => {
                    *slot = TicketSlot::Pending;
                    slot = self
                        .ticket
                        .ready
                        .wait(slot)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                }
            }
        }
    }

    /// [`Self::wait_timed`] bounded by a wall-clock budget: `None` if the
    /// ticket has not resolved within `timeout`. The watchdog primitive —
    /// a load generator waiting on a wedged pipeline turns into a
    /// diagnostic failure instead of a hung process. The handle is
    /// consumed either way (dropping an unresolved ticket is safe; the
    /// request still executes and is freed when the dispatcher lets go).
    pub fn wait_timed_for(
        self,
        timeout: Duration,
    ) -> Option<Result<(ServeResponse, f64), BackendError>> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.ticket.lock_slot();
        loop {
            match std::mem::replace(&mut *slot, TicketSlot::Claimed) {
                TicketSlot::Ready(result, latency_ns) => {
                    return Some(result.map(|r| (r, latency_ns)));
                }
                TicketSlot::Claimed => unreachable!("wait consumes the handle"),
                TicketSlot::Pending => {
                    *slot = TicketSlot::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (guard, _) = self
                        .ticket
                        .ready
                        .wait_timeout(slot, deadline - now)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                    slot = guard;
                }
            }
        }
    }

    /// Non-blocking peek: `None` while the request is still queued or
    /// executing, `Some` once resolved (the handle can then be `wait`ed
    /// for the same answer without blocking).
    pub fn try_wait(&self) -> Option<Result<ServeResponse, BackendError>> {
        let slot = self.ticket.lock_slot();
        match &*slot {
            TicketSlot::Ready(result, _) => Some(result.clone()),
            TicketSlot::Pending => None,
            TicketSlot::Claimed => unreachable!("wait consumes the handle"),
        }
    }
}

/// A request travelling through the queue: payload, completion ticket and
/// the arrival instant latency is measured from.
struct QueuedRequest {
    input: SharedInput,
    ticket: Arc<Ticket>,
    arrival: Instant,
    /// Shedding deadline, if the request carries one: the expiry instant
    /// (`arrival + budget`) plus the original budget in µs for the typed
    /// error. Checked by the dispatcher before any compute.
    deadline: Option<(Instant, u64)>,
    /// Tenant id for quota accounting and weighted-fair selection. The
    /// single-class paths submit as tenant 0.
    tenant: u32,
    /// The result-cache key for handle-submitted requests that missed the
    /// cache at admission: retire memoizes the computed result under it.
    /// `None` for inline-payload requests — the cache is strictly a
    /// handle-path feature (handles are content hashes; inline payloads
    /// would need hashing per request, costing the O(n) the store exists
    /// to avoid).
    cache_key: Option<(u64, u64)>,
    /// Whether the response should carry a certified error bound
    /// ([`ServeResponse::err_bound`]) — computed by retire from the same
    /// shared input, so the bound always describes the exact operands that
    /// produced the value.
    errbound: bool,
}

impl Drop for QueuedRequest {
    /// The backstop that makes "no `ResponseHandle` can hang" a structural
    /// guarantee rather than a code-path audit: wherever a request is
    /// dropped — a dispatcher panic unwinding a gathered batch or the
    /// in-flight deque, the shutdown drain, anywhere — an unresolved
    /// ticket is failed here so its waiter always wakes. The normal path
    /// resolves the ticket first, making this a no-op.
    fn drop(&mut self) {
        let mut slot = self.ticket.lock_slot();
        if matches!(*slot, TicketSlot::Pending) {
            *slot = TicketSlot::Ready(
                Err(BackendError::Runtime(
                    "request dropped before completion".to_string(),
                )),
                0.0,
            );
            self.ticket.ready.notify_all();
        }
    }
}

/// Monotonic pipeline counters (snapshot via [`AsyncDotService::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AsyncServeStats {
    /// Requests accepted into the queue.
    pub enqueued: u64,
    /// Requests whose ticket has been resolved.
    pub completed: u64,
    /// Arrival batches the dispatcher drained from the queue.
    pub arrival_batches: u64,
    /// Pool dispatches posted (one per fused group, one per shard).
    pub dispatches: u64,
    /// High-water mark of the queue — never exceeds the configured depth
    /// (the backpressure bound, property-pinned).
    pub max_queue_depth: usize,
    /// Wall time during which at least one dispatch was in flight (union
    /// of posted→finished intervals, ended at each dispatch's actual latch
    /// completion) — the numerator of pool utilization.
    pub busy_ns: f64,
    /// Requests shed in-queue with the typed `DeadlineExceeded` error —
    /// their deadline expired before the dispatcher reached them, so they
    /// consumed no compute. A subset of `completed`.
    pub deadline_shed: u64,
    /// Requests shed at admission because their tenant was at its quota
    /// (summed over tenants). They never entered the queue, so they are
    /// part of neither `enqueued` nor `completed`.
    pub quota_shed: u64,
    /// Handle-submitted requests answered from the result cache. They
    /// complete without ever entering the queue, so the conservation
    /// identity is `completed == enqueued + cache_hits` at quiescence
    /// (plus shutdown-drained requests, which also resolve).
    pub cache_hits: u64,
}

#[derive(Default)]
struct Counters {
    completed: AtomicU64,
    arrival_batches: AtomicU64,
    dispatches: AtomicU64,
    busy_ns: AtomicU64,
    deadline_shed: AtomicU64,
    cache_hits: AtomicU64,
}

/// One posted-but-not-retired pool dispatch.
struct InFlight {
    /// When the dispatch was posted (for the busy-interval union).
    posted: Instant,
    kind: InFlightKind,
}

enum InFlightKind {
    Fused {
        pending: PendingDispatch<f64>,
        requests: Vec<QueuedRequest>,
    },
    Sharded {
        pending: PendingDispatch<f64>,
        request: QueuedRequest,
    },
}

impl InFlight {
    fn is_done(&self) -> bool {
        match &self.kind {
            InFlightKind::Fused { pending, .. } => pending.is_done(),
            InFlightKind::Sharded { pending, .. } => pending.is_done(),
        }
    }
}

/// The asynchronous serving engine (see the module docs): an inner
/// [`DotService`] over a *detached* pool, fed by the bounded submission
/// queue and the dispatcher thread. The synchronous API remains available
/// as [`AsyncDotService::submit_wait`] — submit-then-wait over the queue,
/// bit-identical to [`DotService::submit_batch`] at the same `T`.
pub struct AsyncDotService {
    service: Arc<DotService>,
    queue: Arc<BoundedQueue<QueuedRequest>>,
    counters: Arc<Counters>,
    tenants: Arc<TenantTable>,
    store: Arc<OperandStore>,
    cache: Arc<ResultCache>,
    faults: Option<Arc<FaultInjector>>,
    dispatcher: Option<JoinHandle<()>>,
    opts: AsyncOptions,
    /// Verify-on-hit sampling rate (`ServeConfig::verify_hit_rate`,
    /// clamped to `0.0..=1.0` at construction).
    verify_rate: f64,
    /// Cache hits seen so far — the deterministic sampling counter
    /// ([`Self::sample_hit`]).
    hit_counter: AtomicU64,
}

impl AsyncDotService {
    /// Build the pipeline: resolves the inner service over a detached pool
    /// (the dispatcher never executes chunks inline), then spawns the
    /// dispatcher thread.
    pub fn new(cfg: ServeConfig, opts: AsyncOptions) -> Result<Self, BackendError> {
        Self::new_with_faults(cfg, opts, None)
    }

    /// [`Self::new`] with a deterministic fault injector threaded through
    /// the pool workers and the dispatcher (chaos tests and
    /// `serve-bench --chaos`). `None` is the production path: every
    /// injection site reduces to one null check.
    pub fn new_with_faults(
        cfg: ServeConfig,
        opts: AsyncOptions,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Self, BackendError> {
        Self::new_with_qos(cfg, opts, None, faults)
    }

    /// [`Self::new_with_faults`] with a multi-tenant QoS policy. `Some`
    /// switches the dispatcher from single-class FIFO to weighted-fair
    /// deficit-round-robin across tenants (deadline-urgent requests first
    /// within each tenant) and arms the per-tenant admission quotas;
    /// `None` keeps the exact pre-QoS FIFO behavior. Either way the
    /// numerics are untouched: scheduling decides where and when a request
    /// runs, never what it computes.
    pub fn new_with_qos(
        cfg: ServeConfig,
        opts: AsyncOptions,
        qos: Option<QosPolicy>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Self, BackendError> {
        let opts = AsyncOptions {
            queue_depth: opts.queue_depth.max(1),
            batch_max: opts.batch_max.max(1),
            ..opts
        };
        let pool = Arc::new(ThreadPool::new_detached_with_faults(
            cfg.threads.max(1),
            faults.clone(),
        ));
        let verify_rate = cfg.verify_hit_rate.clamp(0.0, 1.0);
        let service = Arc::new(DotService::with_pool(cfg, pool)?);
        let queue = Arc::new(BoundedQueue::new(opts.queue_depth));
        let counters = Arc::new(Counters::default());
        let tenants = Arc::new(TenantTable::new(qos.clone()));
        let store = Arc::new(OperandStore::new(STORE_DEFAULT_CAPACITY_BYTES));
        let cache = Arc::new(ResultCache::new(CACHE_DEFAULT_ENTRIES));
        let dispatcher = {
            let service = Arc::clone(&service);
            let queue = Arc::clone(&queue);
            let counters = Arc::clone(&counters);
            let tenants = Arc::clone(&tenants);
            let cache = Arc::clone(&cache);
            let faults = faults.clone();
            std::thread::Builder::new()
                .name("kahan-serve-dispatch".to_string())
                .spawn(move || {
                    dispatcher_main(service, queue, counters, tenants, cache, opts, qos, faults)
                })
                .expect("spawn serve dispatcher")
        };
        Ok(Self {
            service,
            queue,
            counters,
            tenants,
            store,
            cache,
            faults,
            dispatcher: Some(dispatcher),
            opts,
            verify_rate,
            hit_counter: AtomicU64::new(0),
        })
    }

    /// The inner synchronous service (kernel specs, threshold, pool, the
    /// classic `ServeStats` counters).
    pub fn service(&self) -> &Arc<DotService> {
        &self.service
    }

    /// Worker count the pipeline schedules over.
    pub fn threads(&self) -> usize {
        self.service.threads()
    }

    /// The pipeline tuning in effect (depth and batch bounds clamped).
    pub fn options(&self) -> AsyncOptions {
        self.opts
    }

    /// Submit one request; blocks while the queue is at depth (the
    /// backpressure contract). Invalid requests fail here, before
    /// enqueueing — the returned error is the same the synchronous path
    /// raises, and nothing enters the pipeline.
    pub fn submit(&self, input: SharedInput) -> Result<ResponseHandle, BackendError> {
        self.submit_with_arrival(input, Instant::now())
    }

    /// [`Self::submit`] with an explicit arrival instant to measure
    /// latency from. An open-loop load generator passes the *intended*
    /// arrival time, so time spent blocked on backpressure counts as
    /// queueing delay instead of being coordinated-omitted.
    pub fn submit_with_arrival(
        &self,
        input: SharedInput,
        arrival: Instant,
    ) -> Result<ResponseHandle, BackendError> {
        self.submit_with_deadline(input, arrival, self.opts.deadline)
    }

    /// [`Self::submit_with_arrival`] with a per-request deadline override
    /// (the wire front-end's optional deadline field lands here). `None`
    /// means no deadline for *this* request, regardless of the service
    /// default.
    pub fn submit_with_deadline(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
    ) -> Result<ResponseHandle, BackendError> {
        self.submit_with_opts(input, arrival, deadline, 0, false)
    }

    /// The fully-general blocking submit: explicit arrival instant,
    /// per-request deadline override, tenant id, and whether the response
    /// should carry a certified error bound ([`ServeResponse::err_bound`]).
    /// A tenant at its configured quota is shed here with the typed
    /// [`BackendError::QuotaExceeded`] error — nothing enters the queue,
    /// and unlike a full queue the call does not block, because waiting
    /// cannot help until the tenant's own queued work drains.
    pub fn submit_with_opts(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        errbound: bool,
    ) -> Result<ResponseHandle, BackendError> {
        input.view().check(self.service.spec_for(&input.view()))?;
        self.enqueue(input, arrival, deadline, tenant, None, errbound)
    }

    /// Quota admission: one check shared by both submit paths. `false`
    /// means the request was counted as quota-shed (exactly once) and must
    /// not enqueue. The `QuotaAdmissionReject` fault site injects the same
    /// observable outcome on an armed trigger.
    fn admit(&self, tenant: u32) -> bool {
        if let Some(inj) = &self.faults {
            if inj.fire(FaultSite::QuotaAdmissionReject) {
                self.tenants.force_quota_shed(tenant);
                return false;
            }
        }
        self.tenants.admit(tenant)
    }

    /// Enqueue an already-validated request (both submit paths check once,
    /// then land here). `cache_key` is `Some` only for handle-submitted
    /// requests that missed the result cache: retire memoizes under it.
    fn enqueue(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        cache_key: Option<(u64, u64)>,
        errbound: bool,
    ) -> Result<ResponseHandle, BackendError> {
        if !self.admit(tenant) {
            return Err(BackendError::QuotaExceeded { tenant });
        }
        let ticket = Arc::new(Ticket::new());
        let queued = QueuedRequest {
            input,
            ticket: Arc::clone(&ticket),
            arrival,
            deadline: deadline.map(|d| (arrival + d, d.as_micros() as u64)),
            tenant,
            cache_key,
            errbound,
        };
        self.queue.push(queued).map_err(|_| {
            self.tenants.unadmit(tenant);
            BackendError::Runtime("service is shut down".to_string())
        })?;
        Ok(ResponseHandle { ticket })
    }

    /// Non-blocking [`Self::submit`]: a full queue returns
    /// [`TrySubmit::Busy`] (nothing enqueued, caller may retry) instead of
    /// blocking. Invalid requests still fail with the usual validation
    /// error; a closed queue fails with the usual shutdown error.
    pub fn try_submit(&self, input: SharedInput) -> Result<TrySubmit, BackendError> {
        self.try_submit_with_arrival(input, Instant::now())
    }

    /// [`Self::try_submit`] with an explicit arrival instant to measure
    /// latency from (same contract as [`Self::submit_with_arrival`]).
    pub fn try_submit_with_arrival(
        &self,
        input: SharedInput,
        arrival: Instant,
    ) -> Result<TrySubmit, BackendError> {
        self.try_submit_with_deadline(input, arrival, self.opts.deadline)
    }

    /// [`Self::try_submit_with_arrival`] with a per-request deadline
    /// override (same contract as [`Self::submit_with_deadline`]).
    pub fn try_submit_with_deadline(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
    ) -> Result<TrySubmit, BackendError> {
        self.try_submit_with_opts(input, arrival, deadline, 0, false)
    }

    /// The fully-general non-blocking submit: explicit arrival instant,
    /// deadline override, tenant id, and error-bound opt-in. A tenant at
    /// quota returns [`TrySubmit::Quota`] — the wire server maps it to the
    /// QUOTA error frame, distinct from the BUSY frame a full queue
    /// produces.
    pub fn try_submit_with_opts(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        errbound: bool,
    ) -> Result<TrySubmit, BackendError> {
        input.view().check(self.service.spec_for(&input.view()))?;
        self.try_enqueue(input, arrival, deadline, tenant, None, errbound)
    }

    /// Non-blocking enqueue shared by the payload and handle try-submit
    /// paths (quota check, then `try_push`).
    fn try_enqueue(
        &self,
        input: SharedInput,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        cache_key: Option<(u64, u64)>,
        errbound: bool,
    ) -> Result<TrySubmit, BackendError> {
        if !self.admit(tenant) {
            return Ok(TrySubmit::Quota);
        }
        let ticket = Arc::new(Ticket::new());
        let queued = QueuedRequest {
            input,
            ticket: Arc::clone(&ticket),
            arrival,
            deadline: deadline.map(|d| (arrival + d, d.as_micros() as u64)),
            tenant,
            cache_key,
            errbound,
        };
        match self.queue.try_push(queued) {
            Ok(()) => Ok(TrySubmit::Accepted(ResponseHandle { ticket })),
            Err((queued, TryPush::Full)) => {
                // The drop backstop resolves the ticket with an error, but
                // no handle was handed out, so nothing observes it.
                self.tenants.unadmit(tenant);
                drop(queued);
                Ok(TrySubmit::Busy)
            }
            Err((queued, TryPush::Closed)) => {
                self.tenants.unadmit(tenant);
                drop(queued);
                Err(BackendError::Runtime("service is shut down".to_string()))
            }
        }
    }

    /// The synchronous API over the pipeline: submit every request, then
    /// wait for all of them, returning responses in submission order —
    /// bit-identical to [`DotService::submit_batch`] at the same `T`
    /// (property-pinned). Like `submit_batch`, a batch containing an
    /// invalid request fails atomically before anything is enqueued.
    pub fn submit_wait(&self, inputs: &[SharedInput]) -> Result<Vec<ServeResponse>, BackendError> {
        for input in inputs {
            input.view().check(self.service.spec_for(&input.view()))?;
        }
        let handles: Vec<ResponseHandle> = inputs
            .iter()
            .map(|input| {
                self.enqueue(input.clone(), Instant::now(), self.opts.deadline, 0, None, false)
            })
            .collect::<Result<_, _>>()?;
        handles.into_iter().map(ResponseHandle::wait).collect()
    }

    /// Snapshot of the pipeline counters.
    pub fn stats(&self) -> AsyncServeStats {
        let (enqueued, max_queue_depth) = self.queue.counters();
        AsyncServeStats {
            enqueued,
            completed: self.counters.completed.load(Ordering::Relaxed),
            arrival_batches: self.counters.arrival_batches.load(Ordering::Relaxed),
            dispatches: self.counters.dispatches.load(Ordering::Relaxed),
            max_queue_depth,
            busy_ns: self.counters.busy_ns.load(Ordering::Relaxed) as f64,
            deadline_shed: self.counters.deadline_shed.load(Ordering::Relaxed),
            quota_shed: self.tenants.total_quota_shed(),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
        }
    }

    /// Per-tenant accounting snapshot, in ascending tenant-id order. A
    /// tenant appears once admission has seen it — including tenants whose
    /// every request was quota-shed. Empty until the first tenant-tagged
    /// (or plain, i.e. tenant-0) submission.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        self.tenants.snapshot()
    }

    /// The QoS policy the dispatcher schedules with (`None` means the
    /// single-class FIFO path).
    pub fn qos(&self) -> Option<&QosPolicy> {
        self.tenants.policy.as_ref()
    }

    /// The resident operand store backing handle-based submission.
    pub fn store(&self) -> &Arc<OperandStore> {
        &self.store
    }

    /// Register an operand vector in the resident store and return its
    /// content-addressed handle. Re-registering identical contents returns
    /// the same handle with `fresh == false`; a vector that cannot fit
    /// fails with the typed [`BackendError::StoreFull`] and nothing is
    /// evicted on its behalf.
    pub fn register_operand(&self, data: Arc<AlignedVec>) -> Result<RegisterOutcome, BackendError> {
        self.store.register(data).map_err(|e| match e {
            StoreError::Full {
                requested,
                capacity,
            } => BackendError::StoreFull {
                requested,
                capacity,
            },
            StoreError::Collision { handle } => BackendError::Runtime(format!(
                "operand handle collision on {handle:#018x}: distinct contents share a truncated digest"
            )),
        })
    }

    /// Release a resident handle. Returns `true` if the handle was
    /// resident (idempotent: a second release returns `false`). In-flight
    /// requests that already resolved the handle keep their `Arc` to the
    /// operand — release only drops the store's reference, never memory a
    /// reader still holds.
    pub fn release_operand(&self, handle: u64) -> bool {
        self.store.release(handle)
    }

    /// Snapshot of the operand-store counters.
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Snapshot of the result-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Resolve a handle pair against the store — in order, so a request
    /// naming two unknown handles deterministically reports the first —
    /// and validate the resulting dot input exactly as a payload submit
    /// would. Resolution happens *before* any cache probe: the cache
    /// accelerates resident operands, it never resurrects released ones.
    /// With store verification armed
    /// ([`OperandStore::set_verify_on_lookup`]) each lookup re-hashes the
    /// resident bytes first; a digest mismatch quarantines the operand and
    /// fails the request with the typed [`BackendError::CorruptOperand`].
    fn resolve_handles(&self, a: u64, b: u64) -> Result<SharedInput, BackendError> {
        // Injected store corruption: flip a bit in operand `a`'s resident
        // buffer before the scrub-gated lookup below, so an armed trigger
        // exercises the full detect → quarantine → typed-error path. The
        // site is outside `FaultSite::IN_PROCESS` — it only runs where the
        // scrubber is armed to catch it.
        if let Some(inj) = &self.faults {
            if inj.fire(FaultSite::StoreBitFlip) {
                self.store.corrupt_resident(a);
            }
        }
        let x = match self.store.lookup_verified(a) {
            Ok(Some(x)) => x,
            Ok(None) => return Err(BackendError::UnknownHandle { handle: a }),
            Err(handle) => return Err(BackendError::CorruptOperand { handle }),
        };
        let y = match self.store.lookup_verified(b) {
            Ok(Some(y)) => y,
            Ok(None) => return Err(BackendError::UnknownHandle { handle: b }),
            Err(handle) => return Err(BackendError::CorruptOperand { handle }),
        };
        let input = SharedInput::Dot(x, y);
        input.view().check(self.service.spec_for(&input.view()))?;
        Ok(input)
    }

    /// Deterministic verify-on-hit sampler: hit `k` (zero-based) is
    /// sampled iff the integer part of `(k+1)·rate` exceeds that of
    /// `k·rate` — exactly `⌈rate·H⌉` of the first `H` hits, evenly
    /// spaced, with no RNG state. Rate 0 never samples (the counter is
    /// not even touched, keeping the path bit-for-bit identical to the
    /// pre-verification pipeline); rate 1 samples every hit.
    fn sample_hit(&self) -> bool {
        if self.verify_rate <= 0.0 {
            return false;
        }
        let k = self.hit_counter.fetch_add(1, Ordering::Relaxed);
        ((k + 1) as f64 * self.verify_rate) as u64 > (k as f64 * self.verify_rate) as u64
    }

    /// Verify-on-hit: for a sampled cache hit, recompute the dot product
    /// from the resolved operands and bit-compare against the memoized
    /// value. A match returns the hit (counted under
    /// [`CacheStats::verified`]); a mismatch — or a recompute error —
    /// evicts the poisoned entry (counted under [`CacheStats::poisoned`])
    /// and returns `None`, so the caller falls through to a normal
    /// enqueue-and-memoize miss. The recompute runs the synchronous
    /// service path at the same thread count, so by the parity contract a
    /// clean entry always matches bit-for-bit.
    fn verify_hit(
        &self,
        hit: CachedResult,
        key: (u64, u64),
        input: &SharedInput,
    ) -> Option<CachedResult> {
        if !self.sample_hit() {
            return Some(hit);
        }
        match self.service.submit(&input.view()) {
            Ok(resp) if resp.value.to_bits() == hit.bits => {
                self.cache.note_verified();
                Some(hit)
            }
            _ => {
                self.cache.evict_poisoned(key);
                None
            }
        }
    }

    /// Resolve a result-cache hit immediately: the ticket completes with
    /// the memoized value bits and execution path (bit-identical to the
    /// recomputation, by the parity contract) before the handle is
    /// returned. A hit counts as admitted *and* completed for its tenant —
    /// preserving `completed == admitted` at quiescence — and never
    /// occupies quota or the queue.
    fn cache_hit_response(
        &self,
        hit: CachedResult,
        arrival: Instant,
        tenant: u32,
        err_bound: Option<f64>,
    ) -> ResponseHandle {
        let ticket = Arc::new(Ticket::new());
        self.tenants.cache_hit(tenant);
        self.counters.completed.fetch_add(1, Ordering::Relaxed);
        self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
        let latency = Instant::now().saturating_duration_since(arrival);
        ticket.complete(
            Ok(ServeResponse {
                value: f64::from_bits(hit.bits),
                n: hit.n,
                path: hit.path,
                err_bound,
            }),
            latency.as_nanos() as f64,
        );
        ResponseHandle { ticket }
    }

    /// Submit a dot product by resident handles (blocking, default
    /// deadline, tenant 0). A result-cache hit resolves immediately
    /// without touching the queue; a miss enqueues normally and retire
    /// memoizes the computed result under `(a, b)`.
    pub fn submit_handles(&self, a: u64, b: u64) -> Result<ResponseHandle, BackendError> {
        self.submit_handles_with_opts(a, b, Instant::now(), self.opts.deadline, 0, false)
    }

    /// The fully-general blocking handle submit: explicit arrival instant,
    /// per-request deadline override, tenant id, and error-bound opt-in.
    /// Unknown handles fail with the typed [`BackendError::UnknownHandle`]
    /// before any quota or queue interaction.
    pub fn submit_handles_with_opts(
        &self,
        a: u64,
        b: u64,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        errbound: bool,
    ) -> Result<ResponseHandle, BackendError> {
        let input = self.resolve_handles(a, b)?;
        if let Some(hit) = self.cache.get((a, b)) {
            if let Some(hit) = self.verify_hit(hit, (a, b), &input) {
                let eb = errbound.then(|| self.service.err_bound_for(&input.view()));
                return Ok(self.cache_hit_response(hit, arrival, tenant, eb));
            }
        }
        self.enqueue(input, arrival, deadline, tenant, Some((a, b)), errbound)
    }

    /// The fully-general non-blocking handle submit (the wire front-end's
    /// DOT_HANDLES opcode lands here). Same shed semantics as
    /// [`Self::try_submit_with_opts`]: [`TrySubmit::Quota`] at quota,
    /// [`TrySubmit::Busy`] on a full queue — but a result-cache hit is
    /// always accepted, since it consumes neither quota nor queue depth.
    /// A hit whose verify-on-hit sample fails its bit-compare is treated
    /// as a miss: the poisoned entry is evicted and the request proceeds
    /// through the normal admission path.
    pub fn try_submit_handles_with_opts(
        &self,
        a: u64,
        b: u64,
        arrival: Instant,
        deadline: Option<Duration>,
        tenant: u32,
        errbound: bool,
    ) -> Result<TrySubmit, BackendError> {
        let input = self.resolve_handles(a, b)?;
        if let Some(hit) = self.cache.get((a, b)) {
            if let Some(hit) = self.verify_hit(hit, (a, b), &input) {
                let eb = errbound.then(|| self.service.err_bound_for(&input.view()));
                return Ok(TrySubmit::Accepted(
                    self.cache_hit_response(hit, arrival, tenant, eb),
                ));
            }
        }
        self.try_enqueue(input, arrival, deadline, tenant, Some((a, b)), errbound)
    }
}

impl Drop for AsyncDotService {
    /// Shutdown is a drain, not an abort: close the queue (new submits
    /// fail fast), let the dispatcher deliver everything already accepted
    /// — queued and in-flight — and join it. Outstanding
    /// [`ResponseHandle`]s stay valid afterwards: their tickets were
    /// resolved during the drain.
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for AsyncDotService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AsyncDotService")
            .field("service", &self.service)
            .field("queue_depth", &self.opts.queue_depth)
            .field("batch_window", &self.opts.batch_window)
            .field("batch_max", &self.opts.batch_max)
            .field("overlap", &self.opts.overlap)
            .finish()
    }
}

/// The dispatcher thread: gather → plan → post → retire, with the posting
/// and retiring decoupled so the pool never idles between arrival batches.
/// The loop body is panic-guarded; if it ever unwinds (a bug, not a
/// workload condition — worker panics are caught per dispatch), the
/// cleanup path still resolves every remaining queued ticket with an
/// error so no `ResponseHandle` can hang.
fn dispatcher_main(
    service: Arc<DotService>,
    queue: Arc<BoundedQueue<QueuedRequest>>,
    counters: Arc<Counters>,
    tenants: Arc<TenantTable>,
    cache: Arc<ResultCache>,
    opts: AsyncOptions,
    qos: Option<QosPolicy>,
    faults: Option<Arc<FaultInjector>>,
) {
    let run = {
        let (service, queue, counters, tenants, cache, faults) =
            (&service, &queue, &counters, &tenants, &cache, &faults);
        move || {
            dispatcher_loop(
                service,
                queue,
                counters,
                tenants,
                cache,
                opts,
                qos,
                faults.as_deref(),
            )
        }
    };
    let outcome = catch_unwind(AssertUnwindSafe(run));
    // Normal exit already drained everything; after a panic, fail whatever
    // is still queued so waiters wake up.
    queue.close();
    while let Pop::Item(q) = queue.try_pop() {
        tenants.drain_complete(q.tenant);
        q.ticket.complete(
            Err(BackendError::Runtime("serve dispatcher exited".to_string())),
            0.0,
        );
        counters.completed.fetch_add(1, Ordering::Relaxed);
    }
    if let Err(p) = outcome {
        std::panic::resume_unwind(p);
    }
}

/// Per-tenant ready lanes plus the deficit counters backing the
/// weighted-fair dispatcher. Deadline-bearing requests are promoted into
/// their tenant's *urgent* lane and drain before that tenant's normal
/// lane; selection *across* tenants is [`QosPolicy::drr_select`], so one
/// tenant's urgency never taxes another tenant's share.
struct QosState {
    policy: QosPolicy,
    lanes: BTreeMap<u32, TenantLane>,
    deficits: BTreeMap<u32, u64>,
    len: usize,
}

#[derive(Default)]
struct TenantLane {
    urgent: VecDeque<QueuedRequest>,
    normal: VecDeque<QueuedRequest>,
}

impl TenantLane {
    fn len(&self) -> usize {
        self.urgent.len() + self.normal.len()
    }

    fn pop(&mut self) -> Option<QueuedRequest> {
        self.urgent.pop_front().or_else(|| self.normal.pop_front())
    }
}

impl QosState {
    fn new(policy: QosPolicy) -> Self {
        Self {
            policy,
            lanes: BTreeMap::new(),
            deficits: BTreeMap::new(),
            len: 0,
        }
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn enqueue(&mut self, q: QueuedRequest) {
        let lane = self.lanes.entry(q.tenant).or_default();
        if q.deadline.is_some() {
            lane.urgent.push_back(q);
        } else {
            lane.normal.push_back(q);
        }
        self.len += 1;
    }

    /// Pop the next weighted-fair batch (at most `batch_max` requests) in
    /// DRR drain order. FIFO order is preserved within each tenant lane.
    fn next_batch(&mut self, batch_max: usize) -> Vec<QueuedRequest> {
        let pending: BTreeMap<u32, usize> = self
            .lanes
            .iter()
            .filter(|(_, lane)| lane.len() > 0)
            .map(|(&t, lane)| (t, lane.len()))
            .collect();
        let order = self.policy.drr_select(&mut self.deficits, &pending, batch_max);
        let mut batch = Vec::with_capacity(order.len());
        for tenant in order {
            let q = self
                .lanes
                .get_mut(&tenant)
                .and_then(TenantLane::pop)
                .expect("drr_select never over-draws a lane");
            self.len -= 1;
            batch.push(q);
        }
        self.lanes.retain(|_, lane| lane.len() > 0);
        batch
    }
}

fn dispatcher_loop(
    service: &DotService,
    queue: &BoundedQueue<QueuedRequest>,
    counters: &Counters,
    tenants: &TenantTable,
    cache: &ResultCache,
    opts: AsyncOptions,
    qos: Option<QosPolicy>,
    faults: Option<&FaultInjector>,
) {
    let epoch = Instant::now();
    let mut inflight: VecDeque<InFlight> = VecDeque::new();
    // End of the last retired busy interval (ns since epoch), for the
    // interval-union busy accounting.
    let mut busy_end_ns = 0.0f64;
    // Weighted-fair mode holds arrivals in per-tenant lanes; FIFO mode
    // dispatches arrival batches directly.
    let mut backlog = qos.map(QosState::new);
    let mut closed = false;
    loop {
        // Retire whatever already finished (front first: dispatch order).
        while inflight.front().map(InFlight::is_done).unwrap_or(false) {
            let f = inflight.pop_front().unwrap();
            retire(service, counters, tenants, cache, faults, epoch, &mut busy_end_ns, f);
        }
        // Bound dispatcher-side memory.
        while inflight.len() >= MAX_INFLIGHT_DISPATCHES {
            let f = inflight.pop_front().unwrap();
            retire(service, counters, tenants, cache, faults, epoch, &mut busy_end_ns, f);
        }
        // Acquire the next arrivals. With requests already owed to the
        // weighted-fair selector, drain the queue opportunistically and
        // never park — the backlog itself is dispatchable work. Otherwise
        // this is the classic gather path: with work in flight, never park
        // indefinitely on either side — wait for arrivals in short beats
        // and re-check the front dispatch between them, so a long-running
        // dispatch neither blocks admission of new requests (head-of-line)
        // nor delays retiring dispatches that have already finished.
        let backlogged = backlog.as_ref().map_or(false, |b| !b.is_empty());
        let mut arrivals: Vec<QueuedRequest> = Vec::new();
        if !closed {
            if backlogged {
                while arrivals.len() < opts.batch_max {
                    match queue.try_pop() {
                        Pop::Item(q) => arrivals.push(q),
                        Pop::Empty => break,
                        Pop::Closed => {
                            closed = true;
                            break;
                        }
                    }
                }
            } else {
                let first = if inflight.is_empty() {
                    match queue.pop_wait() {
                        Some(q) => q,
                        None => {
                            closed = true;
                            None
                        }
                    }
                } else {
                    match queue.pop_timeout(RETIRE_POLL) {
                        Pop::Item(q) => Some(q),
                        Pop::Empty => continue, // beat elapsed: loop re-checks the front
                        Pop::Closed => {
                            closed = true;
                            None
                        }
                    }
                };
                if let Some(first) = first {
                    arrivals = gather(queue, first, &opts);
                }
            }
        }
        if !arrivals.is_empty() {
            counters.arrival_batches.fetch_add(1, Ordering::Relaxed);
            // Injected dispatcher stall (armed once per arrival batch):
            // models a descheduled dispatcher thread. Arrivals pile into
            // the bounded queue behind backpressure; deadline-bearing
            // requests age toward their shed point.
            if let Some(inj) = faults {
                if let Some(delay) = inj.stall(FaultSite::DispatcherStall) {
                    std::thread::sleep(delay);
                }
            }
        }
        let batch = match &mut backlog {
            Some(state) => {
                for q in arrivals {
                    state.enqueue(q);
                }
                if let Some(inj) = faults {
                    // Injected starvation stall (armed once per non-empty
                    // selection): delays the weighted-fair selection
                    // itself, so every backlogged tenant waits equally —
                    // a liveness fault, not a fairness fault.
                    if !state.is_empty() {
                        if let Some(delay) = inj.stall(FaultSite::StarvationStall) {
                            std::thread::sleep(delay);
                        }
                    }
                }
                state.next_batch(opts.batch_max)
            }
            None => arrivals,
        };
        if !batch.is_empty() {
            dispatch(service, counters, tenants, &mut inflight, batch);
            if !opts.overlap {
                while let Some(f) = inflight.pop_front() {
                    retire(service, counters, tenants, cache, faults, epoch, &mut busy_end_ns, f);
                }
            }
        }
        if closed && backlog.as_ref().map_or(true, QosState::is_empty) {
            for f in inflight.drain(..) {
                retire(service, counters, tenants, cache, faults, epoch, &mut busy_end_ns, f);
            }
            return;
        }
    }
}

/// Drain the arrival batch: everything already queued, then (while the
/// batching window is open and the count bound unmet) whatever arrives
/// before the deadline.
fn gather(
    queue: &BoundedQueue<QueuedRequest>,
    first: QueuedRequest,
    opts: &AsyncOptions,
) -> Vec<QueuedRequest> {
    let deadline = Instant::now() + opts.batch_window;
    let mut batch = vec![first];
    while batch.len() < opts.batch_max {
        match queue.try_pop() {
            Pop::Item(q) => {
                batch.push(q);
                continue;
            }
            Pop::Closed => break,
            Pop::Empty => {}
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match queue.pop_timeout(deadline - now) {
            Pop::Item(q) => batch.push(q),
            _ => break, // window elapsed or queue closed: dispatch what we have
        }
    }
    batch
}

/// Route one drained arrival batch through the scheduler and post it to
/// the pool without blocking: one `run_tasks_async` for the whole fused
/// group, one `run_chunks_async` per sharded request.
fn dispatch(
    service: &DotService,
    counters: &Counters,
    tenants: &TenantTable,
    inflight: &mut VecDeque<InFlight>,
    batch: Vec<QueuedRequest>,
) {
    // Every request in the batch leaves quota occupancy here — whether it
    // sheds on deadline below or goes on to execute — so a tenant's quota
    // gates only admitted-but-undispatched work.
    for q in &batch {
        tenants.release(q.tenant);
    }
    // Deadline shedding happens here, at the last instant before any
    // planning or compute: a request whose deadline expired while it sat
    // in the queue (or in the batching window) resolves immediately with
    // a typed error and never touches the pool. Shedding before the plan
    // keeps the scheduler's fuse/shard decision identical for the
    // requests that do run.
    let now = Instant::now();
    let batch: Vec<QueuedRequest> = batch
        .into_iter()
        .filter_map(|q| match q.deadline {
            Some((expires, budget_us)) if now >= expires => {
                let latency = now.saturating_duration_since(q.arrival);
                q.ticket.complete(
                    Err(BackendError::DeadlineExceeded { budget_us }),
                    latency.as_nanos() as f64,
                );
                counters.deadline_shed.fetch_add(1, Ordering::Relaxed);
                counters.completed.fetch_add(1, Ordering::Relaxed);
                tenants.shed_deadline(q.tenant);
                None
            }
            _ => Some(q),
        })
        .collect();
    if batch.is_empty() {
        return;
    }
    let plan = service
        .scheduler
        .plan_lens(batch.iter().map(|q| q.input.updates()));
    let mut slots: Vec<Option<QueuedRequest>> = batch.into_iter().map(Some).collect();
    let pool = service.pool();
    if !plan.fused.is_empty() {
        let requests: Vec<QueuedRequest> = plan
            .fused
            .iter()
            .map(|&i| slots[i].take().expect("fused index planned once"))
            .collect();
        let inputs: Vec<SharedInput> = requests.iter().map(|q| q.input.clone()).collect();
        let (dot_fn, sum_fn) = (service.dot_fn, service.sum_fn);
        let posted = Instant::now();
        let pending = pool.run_tasks_async(inputs.len(), move |i| match inputs[i].view() {
            KernelInput::Dot(x, y) => dot_fn(x, y),
            KernelInput::Sum(x) => sum_fn(x),
        });
        counters.dispatches.fetch_add(1, Ordering::Relaxed);
        inflight.push_back(InFlight {
            posted,
            kind: InFlightKind::Fused { pending, requests },
        });
    }
    for &i in &plan.sharded {
        let request = slots[i].take().expect("sharded index planned once");
        let posted = Instant::now();
        let pending = match &request.input {
            SharedInput::Dot(x, y) => {
                let (x, y) = (Arc::clone(x), Arc::clone(y));
                let f = service.dot_fn;
                pool.run_chunks_async(x.len(), CACHELINE_F64, move |_, r| {
                    f(&x[r.clone()], &y[r])
                })
            }
            SharedInput::Sum(x) => {
                let x = Arc::clone(x);
                let f = service.sum_fn;
                pool.run_chunks_async(x.len(), CACHELINE_F64, move |_, r| f(&x[r]))
            }
        };
        counters.dispatches.fetch_add(1, Ordering::Relaxed);
        inflight.push_back(InFlight {
            posted,
            kind: InFlightKind::Sharded { pending, request },
        });
    }
}

/// Fold one dispatch's `[posted, finished]` span into the busy-interval
/// union. Retires happen in dispatch order, so extending from
/// `max(posted, previous end)` to this dispatch's finish never
/// double-counts, never counts idle gaps between dispatches, and a
/// dispatch that finished inside an already-accounted span adds nothing.
/// `finished` is the latch's completion instant, not the (possibly later)
/// retire time — the dispatcher lingering in a batching window must not
/// inflate pool utilization.
fn account_busy(
    counters: &Counters,
    epoch: Instant,
    busy_end_ns: &mut f64,
    posted: Instant,
    finished: Instant,
) {
    let posted_ns = posted.saturating_duration_since(epoch).as_nanos() as f64;
    let end_ns = finished.saturating_duration_since(epoch).as_nanos() as f64;
    let start_ns = posted_ns.max(*busy_end_ns);
    if end_ns > start_ns {
        counters
            .busy_ns
            .fetch_add((end_ns - start_ns) as u64, Ordering::Relaxed);
        *busy_end_ns = end_ns;
    }
}

/// Wait out one dispatch (usually already finished), account it, and
/// resolve its tickets. Counters and busy time are updated *before* any
/// ticket resolves, so a waiter that reads `stats()` the moment its
/// ticket wakes never sees the dispatch half-accounted. A worker panic is
/// contained here: the affected requests fail with a runtime error, the
/// pool and the pipeline keep serving.
fn retire(
    service: &DotService,
    counters: &Counters,
    tenants: &TenantTable,
    cache: &ResultCache,
    faults: Option<&FaultInjector>,
    epoch: Instant,
    busy_end_ns: &mut f64,
    inflight: InFlight,
) {
    let panicked = || BackendError::Runtime("worker panicked during execution".to_string());
    let posted = inflight.posted;
    match inflight.kind {
        InFlightKind::Fused { pending, requests } => {
            match catch_unwind(AssertUnwindSafe(|| pending.wait_finished())) {
                Ok((values, finished)) => {
                    let now = Instant::now();
                    let updates: u64 = requests.iter().map(|q| q.input.updates() as u64).sum();
                    service.record(requests.len() as u64, 0, updates);
                    counters
                        .completed
                        .fetch_add(requests.len() as u64, Ordering::Relaxed);
                    account_busy(counters, epoch, busy_end_ns, posted, finished);
                    for (q, value) in requests.iter().zip(values) {
                        let response = ServeResponse {
                            value,
                            n: q.input.updates(),
                            path: ExecPath::Fused,
                            err_bound: q
                                .errbound
                                .then(|| service.err_bound_for(&q.input.view())),
                        };
                        // Memoize on success only: a handle-submitted miss
                        // carries its key, so the next identical submit
                        // replays this exact value and path. The error
                        // bound is never cached: it is recomputed per
                        // request, so a poisoned entry cannot smuggle a
                        // stale certificate.
                        if let Some(key) = q.cache_key {
                            cache.insert(
                                key,
                                CachedResult {
                                    bits: value.to_bits(),
                                    n: response.n,
                                    path: ExecPath::Fused,
                                },
                            );
                            // Injected cache poisoning: flip the memoized
                            // bits right after insert, so a later sampled
                            // hit must fail its bit-compare and evict.
                            if let Some(inj) = faults {
                                if inj.fire(FaultSite::CachePoison) {
                                    cache.poison(key);
                                }
                            }
                        }
                        tenants.complete(q.tenant);
                        let latency = now.saturating_duration_since(q.arrival);
                        q.ticket.complete(Ok(response), latency.as_nanos() as f64);
                    }
                }
                Err(_) => {
                    let now = Instant::now();
                    counters
                        .completed
                        .fetch_add(requests.len() as u64, Ordering::Relaxed);
                    account_busy(counters, epoch, busy_end_ns, posted, now);
                    for q in &requests {
                        tenants.complete(q.tenant);
                        let latency = now.saturating_duration_since(q.arrival);
                        q.ticket.complete(Err(panicked()), latency.as_nanos() as f64);
                    }
                }
            }
        }
        InFlightKind::Sharded { pending, request } => {
            let n = request.input.updates();
            match catch_unwind(AssertUnwindSafe(|| pending.wait_finished())) {
                Ok((partials, finished)) => {
                    let value = compensated_tree_reduce(&partials);
                    service.record(0, 1, n as u64);
                    counters.completed.fetch_add(1, Ordering::Relaxed);
                    account_busy(counters, epoch, busy_end_ns, posted, finished);
                    let response = ServeResponse {
                        value,
                        n,
                        path: ExecPath::Sharded,
                        err_bound: request
                            .errbound
                            .then(|| service.err_bound_for(&request.input.view())),
                    };
                    if let Some(key) = request.cache_key {
                        cache.insert(
                            key,
                            CachedResult {
                                bits: value.to_bits(),
                                n,
                                path: ExecPath::Sharded,
                            },
                        );
                        if let Some(inj) = faults {
                            if inj.fire(FaultSite::CachePoison) {
                                cache.poison(key);
                            }
                        }
                    }
                    tenants.complete(request.tenant);
                    let latency = Instant::now().saturating_duration_since(request.arrival);
                    request
                        .ticket
                        .complete(Ok(response), latency.as_nanos() as f64);
                }
                Err(_) => {
                    let now = Instant::now();
                    counters.completed.fetch_add(1, Ordering::Relaxed);
                    account_busy(counters, epoch, busy_end_ns, posted, now);
                    tenants.complete(request.tenant);
                    let latency = now.saturating_duration_since(request.arrival);
                    request
                        .ticket
                        .complete(Err(panicked()), latency.as_nanos() as f64);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ImplStyle;
    use crate::serve::ThresholdMode;
    use crate::util::rng::Rng;

    fn cfg(threads: usize, threshold: usize) -> ServeConfig {
        ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        }
    }

    fn shared_dot(n: usize, seed: u64) -> SharedInput {
        let mut rng = Rng::new(seed);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        SharedInput::dot(&x, &y)
    }

    #[test]
    fn async_submit_wait_matches_sync_submit_batch_bits() {
        let sizes = [7usize, 500, 1000, 1001, 4096, 63];
        let shared: Vec<SharedInput> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| shared_dot(n, 1000 + i as u64))
            .collect();
        for threads in [1usize, 3] {
            let sync = DotService::new(cfg(threads, 1000)).unwrap();
            let asy = AsyncDotService::new(cfg(threads, 1000), AsyncOptions::default()).unwrap();
            let views: Vec<KernelInput<'_>> = shared.iter().map(SharedInput::view).collect();
            let want = sync.submit_batch(&views).unwrap();
            let got = asy.submit_wait(&shared).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.value.to_bits(), b.value.to_bits(), "n={} T={threads}", a.n);
                assert_eq!(a.path, b.path);
                assert_eq!(a.n, b.n);
            }
        }
    }

    #[test]
    fn try_wait_polls_then_wait_returns_same_result() {
        let asy = AsyncDotService::new(cfg(2, usize::MAX), AsyncOptions::default()).unwrap();
        let input = shared_dot(512, 9);
        let want = asy.service().submit(&input.view()).unwrap();
        let handle = asy.submit(input).unwrap();
        let peeked = loop {
            if let Some(r) = handle.try_wait() {
                break r.unwrap();
            }
            std::thread::yield_now();
        };
        let got = handle.wait().unwrap();
        assert_eq!(peeked.value.to_bits(), got.value.to_bits());
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }

    #[test]
    fn invalid_requests_fail_at_submit_without_entering_the_queue() {
        let asy = AsyncDotService::new(cfg(2, 100), AsyncOptions::default()).unwrap();
        let x = crate::runtime::arena::AlignedVec::copy_from(&[1.0, 2.0]);
        let y = crate::runtime::arena::AlignedVec::copy_from(&[1.0]);
        let bad = SharedInput::Dot(Arc::new(x), Arc::new(y));
        let err = asy.submit(bad).unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        assert_eq!(asy.stats().enqueued, 0);
    }

    #[test]
    fn shutdown_resolves_outstanding_tickets() {
        let asy = AsyncDotService::new(cfg(2, 256), AsyncOptions::default()).unwrap();
        let handles: Vec<(ResponseHandle, SharedInput)> = (0..24)
            .map(|i| {
                let input = shared_dot(64 + (i % 5) * 300, 7000 + i as u64);
                (asy.submit(input.clone()).unwrap(), input)
            })
            .collect();
        drop(asy); // close + drain + join
        for (h, input) in handles {
            let sync = DotService::new(cfg(2, 256)).unwrap();
            let want = sync.submit(&input.view()).unwrap();
            let got = h.wait().expect("shutdown must drain, not drop, requests");
            assert_eq!(got.value.to_bits(), want.value.to_bits());
        }
    }

    #[test]
    fn submit_after_shutdown_fails_cleanly() {
        let asy = AsyncDotService::new(cfg(1, 100), AsyncOptions::default()).unwrap();
        asy.queue.close();
        let err = asy.submit(shared_dot(16, 3)).unwrap_err();
        assert!(matches!(err, BackendError::Runtime(_)));
    }

    #[test]
    fn try_push_reports_full_and_closed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        match q.try_push(3) {
            Err((item, TryPush::Full)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {:?}", other),
        }
        // Draining one slot re-admits.
        assert!(matches!(q.try_pop(), Pop::Item(1)));
        assert!(q.try_push(3).is_ok());
        q.close();
        match q.try_push(4) {
            Err((item, TryPush::Closed)) => assert_eq!(item, 4),
            other => panic!("expected Closed, got {:?}", other),
        }
        // Depth accounting saw the exact bound.
        let (enqueued, max_depth) = q.counters();
        assert_eq!(enqueued, 3);
        assert_eq!(max_depth, 2);
    }

    #[test]
    fn try_submit_accepts_and_matches_sync_bits() {
        let asy = AsyncDotService::new(cfg(2, 1000), AsyncOptions::default()).unwrap();
        let input = shared_dot(700, 77);
        let want = asy.service().submit(&input.view()).unwrap();
        let handle = match asy.try_submit(input).unwrap() {
            TrySubmit::Accepted(h) => h,
            TrySubmit::Busy => panic!("empty queue must admit"),
        };
        let got = handle.wait().unwrap();
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }

    #[test]
    fn try_submit_validates_and_fails_after_shutdown() {
        let asy = AsyncDotService::new(cfg(1, 100), AsyncOptions::default()).unwrap();
        let x = crate::runtime::arena::AlignedVec::copy_from(&[1.0, 2.0]);
        let y = crate::runtime::arena::AlignedVec::copy_from(&[1.0]);
        let bad = SharedInput::Dot(Arc::new(x), Arc::new(y));
        assert!(matches!(
            asy.try_submit(bad),
            Err(BackendError::ShapeMismatch { .. })
        ));
        asy.queue.close();
        assert!(matches!(
            asy.try_submit(shared_dot(16, 5)),
            Err(BackendError::Runtime(_))
        ));
    }

    #[test]
    fn no_overlap_mode_serves_identically() {
        let opts = AsyncOptions {
            overlap: false,
            ..AsyncOptions::default()
        };
        let asy = AsyncDotService::new(cfg(2, 512), opts).unwrap();
        let inputs: Vec<SharedInput> = (0..8)
            .map(|i| shared_dot(100 + i * 130, 40 + i as u64))
            .collect();
        let got = asy.submit_wait(&inputs).unwrap();
        let sync = DotService::new(cfg(2, 512)).unwrap();
        for (input, g) in inputs.iter().zip(&got) {
            let want = sync.submit(&input.view()).unwrap();
            assert_eq!(want.value.to_bits(), g.value.to_bits());
        }
        assert_eq!(asy.stats().completed, 8);
    }

    #[test]
    fn zero_deadline_sheds_with_typed_error_before_compute() {
        // A zero budget expires the instant the request arrives, so every
        // request must shed in-queue: typed error, no dispatch, no compute.
        let opts = AsyncOptions {
            deadline: Some(Duration::ZERO),
            ..AsyncOptions::default()
        };
        let asy = AsyncDotService::new(cfg(2, 1000), opts).unwrap();
        let handles: Vec<ResponseHandle> = (0..6)
            .map(|i| asy.submit(shared_dot(256, 300 + i as u64)).unwrap())
            .collect();
        for h in handles {
            match h.wait() {
                Err(BackendError::DeadlineExceeded { budget_us }) => assert_eq!(budget_us, 0),
                other => panic!("expected DeadlineExceeded, got {other:?}"),
            }
        }
        let stats = asy.stats();
        assert_eq!(stats.deadline_shed, 6);
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.dispatches, 0, "shed requests must never reach the pool");
    }

    #[test]
    fn per_request_deadline_overrides_service_default() {
        // Service default disables deadlines; a generous per-request
        // deadline still completes normally and bit-matches sync.
        let asy = AsyncDotService::new(cfg(2, 1000), AsyncOptions::default()).unwrap();
        let input = shared_dot(512, 91);
        let want = asy.service().submit(&input.view()).unwrap();
        let handle = asy
            .submit_with_deadline(input, Instant::now(), Some(Duration::from_secs(60)))
            .unwrap();
        let got = handle.wait().unwrap();
        assert_eq!(got.value.to_bits(), want.value.to_bits());
        assert_eq!(asy.stats().deadline_shed, 0);
    }

    #[test]
    fn dispatcher_stall_injection_only_delays() {
        use super::super::faults::{FaultPlan, FaultSite};
        let plan = FaultPlan::none().with_stall(
            FaultSite::DispatcherStall,
            1,
            Duration::from_millis(2),
        );
        let injector = crate::serve::faults::FaultInjector::new(plan);
        let asy = AsyncDotService::new_with_faults(
            cfg(2, 1000),
            AsyncOptions::default(),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        let inputs: Vec<SharedInput> = (0..4)
            .map(|i| shared_dot(200 + i * 170, 500 + i as u64))
            .collect();
        let got = asy.submit_wait(&inputs).unwrap();
        let sync = DotService::new(cfg(2, 1000)).unwrap();
        for (input, g) in inputs.iter().zip(&got) {
            let want = sync.submit(&input.view()).unwrap();
            assert_eq!(want.value.to_bits(), g.value.to_bits());
        }
        assert_eq!(injector.fired(FaultSite::DispatcherStall), 1);
    }

    #[test]
    fn qos_policy_parse_accepts_both_forms() {
        let named = QosPolicy::parse("a:3,b:1").unwrap();
        assert_eq!(named.classes().len(), 2);
        assert_eq!(named.name(0), "a");
        assert_eq!(named.weight(0), 3);
        assert_eq!(named.weight(1), 1);
        assert_eq!(named.quota(0), usize::MAX);

        let bare = QosPolicy::parse("3:1").unwrap();
        assert_eq!(bare.classes().len(), 2);
        assert_eq!(bare.name(0), "t0");
        assert_eq!(bare.weight(0), 3);
        assert_eq!(bare.weight(1), 1);

        let quotas = QosPolicy::parse("a:3:16,b:1:8").unwrap();
        assert_eq!(quotas.quota(0), 16);
        assert_eq!(quotas.quota(1), 8);

        // Default quotas: weight-proportional share of the depth, min 1.
        let filled = QosPolicy::parse("a:3,b:1").unwrap().with_default_quotas(64);
        assert_eq!(filled.quota(0), 48);
        assert_eq!(filled.quota(1), 16);

        assert!(QosPolicy::parse("").is_err());
        assert!(QosPolicy::parse("a").is_err());
        assert!(QosPolicy::parse("a:x").is_err());
        assert!(QosPolicy::parse("a:1:y").is_err());
        assert!(QosPolicy::parse(":1").is_err());
    }

    #[test]
    fn drr_select_share_tracks_weights_and_preserves_deficit_carryover() {
        let policy = QosPolicy::parse("heavy:3,light:1").unwrap();
        let mut deficits = BTreeMap::new();
        let mut pending: BTreeMap<u32, usize> = BTreeMap::new();
        pending.insert(0, 10_000);
        pending.insert(1, 10_000);
        let mut taken = [0u64; 2];
        // Many small batches over a permanently backlogged pair: the drain
        // shares must converge to the 3:1 weights.
        for _ in 0..256 {
            for &t in &policy.drr_select(&mut deficits, &pending, 8) {
                taken[t as usize] += 1;
            }
        }
        let total = taken[0] + taken[1];
        assert_eq!(total, 256 * 8);
        let heavy_share = taken[0] as f64 / total as f64;
        assert!(
            (heavy_share - 0.75).abs() < 0.02,
            "heavy share {heavy_share} should converge to 0.75"
        );
    }

    #[test]
    fn drr_select_drains_everything_when_room_allows() {
        let policy = QosPolicy::parse("a:5,b:1").unwrap();
        let mut deficits = BTreeMap::new();
        let mut pending: BTreeMap<u32, usize> = BTreeMap::new();
        pending.insert(0, 3);
        pending.insert(1, 2);
        let order = policy.drr_select(&mut deficits, &pending, 64);
        assert_eq!(order.iter().filter(|&&t| t == 0).count(), 3);
        assert_eq!(order.iter().filter(|&&t| t == 1).count(), 2);
        // Both lanes emptied: deficits reset, no credit hoarding.
        assert!(deficits.values().all(|&d| d == 0));
    }

    #[test]
    fn quota_shed_is_typed_counted_once_and_never_enqueued() {
        // Quota 0 for tenant 0: every submission sheds at admission.
        let policy = QosPolicy::new(vec![TenantClass {
            name: "z".to_string(),
            weight: 1,
            quota: Some(0),
        }]);
        let asy =
            AsyncDotService::new_with_qos(cfg(1, 1000), AsyncOptions::default(), Some(policy), None)
                .unwrap();
        match asy.submit(shared_dot(64, 1)).unwrap_err() {
            BackendError::QuotaExceeded { tenant } => assert_eq!(tenant, 0),
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
        match asy.try_submit(shared_dot(64, 2)).unwrap() {
            TrySubmit::Quota => {}
            other => panic!("expected Quota, got {other:?}"),
        }
        let stats = asy.stats();
        assert_eq!(stats.quota_shed, 2);
        assert_eq!(stats.enqueued, 0, "shed requests must never enqueue");
        assert_eq!(stats.completed, 0);
        let rows = asy.tenant_stats();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].quota_shed, 2);
        assert_eq!(rows[0].admitted, 0, "a shed request is not admitted");
    }

    #[test]
    fn weighted_fair_service_matches_sync_bits_and_accounts_per_tenant() {
        let policy = QosPolicy::parse("a:3,b:1").unwrap();
        let asy =
            AsyncDotService::new_with_qos(cfg(3, 1000), AsyncOptions::default(), Some(policy), None)
                .unwrap();
        let sync = DotService::new(cfg(3, 1000)).unwrap();
        let inputs: Vec<(u32, SharedInput)> = (0..12)
            .map(|i| (i % 2, shared_dot(300 + (i % 5) * 400, 9000 + i as u64)))
            .collect();
        let handles: Vec<(ResponseHandle, &SharedInput)> = inputs
            .iter()
            .map(|(tenant, input)| {
                let h = asy
                    .submit_with_opts(input.clone(), Instant::now(), None, *tenant, false)
                    .unwrap();
                (h, input)
            })
            .collect();
        for (h, input) in handles {
            let want = sync.submit(&input.view()).unwrap();
            let got = h.wait().unwrap();
            assert_eq!(got.value.to_bits(), want.value.to_bits());
        }
        let rows = asy.tenant_stats();
        assert_eq!(rows.len(), 2);
        for row in rows {
            assert_eq!(row.admitted, 6);
            assert_eq!(row.completed, 6, "tenant {} must fully retire", row.tenant);
            assert_eq!(row.quota_shed, 0);
        }
    }

    #[test]
    fn quota_admission_reject_fault_sheds_exactly_once() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::none().with(FaultSite::QuotaAdmissionReject, 1);
        let injector = crate::serve::faults::FaultInjector::new(plan);
        let policy = QosPolicy::parse("a:1").unwrap();
        let asy = AsyncDotService::new_with_qos(
            cfg(2, 1000),
            AsyncOptions::default(),
            Some(policy),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        // First submission hits the armed trigger: typed quota error.
        match asy.submit(shared_dot(128, 11)).unwrap_err() {
            BackendError::QuotaExceeded { tenant } => assert_eq!(tenant, 0),
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
        // Second submission is admitted and completes normally.
        let got = asy.submit(shared_dot(128, 11)).unwrap().wait().unwrap();
        let want = DotService::new(cfg(2, 1000))
            .unwrap()
            .submit(&shared_dot(128, 11).view())
            .unwrap();
        assert_eq!(got.value.to_bits(), want.value.to_bits());
        assert_eq!(injector.fired(FaultSite::QuotaAdmissionReject), 1);
        let rows = asy.tenant_stats();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].quota_shed, 1, "injected shed counted exactly once");
        assert_eq!(rows[0].admitted, 1);
        assert_eq!(rows[0].completed, 1);
    }

    #[test]
    fn starvation_stall_injection_only_delays_selection() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::none().with_stall(
            FaultSite::StarvationStall,
            1,
            Duration::from_millis(2),
        );
        let injector = crate::serve::faults::FaultInjector::new(plan);
        let policy = QosPolicy::parse("a:3,b:1").unwrap();
        let asy = AsyncDotService::new_with_qos(
            cfg(2, 1000),
            AsyncOptions::default(),
            Some(policy),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        let sync = DotService::new(cfg(2, 1000)).unwrap();
        let handles: Vec<(ResponseHandle, SharedInput)> = (0..6)
            .map(|i| {
                let input = shared_dot(200 + i * 150, 600 + i as u64);
                let h = asy
                    .submit_with_opts(input.clone(), Instant::now(), None, (i % 2) as u32, false)
                    .unwrap();
                (h, input)
            })
            .collect();
        for (h, input) in handles {
            let want = sync.submit(&input.view()).unwrap();
            let got = h.wait().expect("stall delays, never drops");
            assert_eq!(got.value.to_bits(), want.value.to_bits());
        }
        assert_eq!(injector.fired(FaultSite::StarvationStall), 1);
    }

    #[test]
    fn shutdown_drains_weighted_fair_backlog() {
        // Close the service while requests sit in the QoS lanes: every
        // ticket must still resolve (drain, not drop).
        let policy = QosPolicy::parse("a:3,b:1").unwrap();
        let asy =
            AsyncDotService::new_with_qos(cfg(2, 256), AsyncOptions::default(), Some(policy), None)
                .unwrap();
        let handles: Vec<(ResponseHandle, SharedInput)> = (0..16)
            .map(|i| {
                let input = shared_dot(64 + (i % 4) * 250, 7100 + i as u64);
                let h = asy
                    .submit_with_opts(input.clone(), Instant::now(), None, (i % 2) as u32, false)
                    .unwrap();
                (h, input)
            })
            .collect();
        drop(asy); // close + drain + join
        let sync = DotService::new(cfg(2, 256)).unwrap();
        for (h, input) in handles {
            let want = sync.submit(&input.view()).unwrap();
            let got = h.wait().expect("shutdown must drain, not drop, requests");
            assert_eq!(got.value.to_bits(), want.value.to_bits());
        }
    }

    fn aligned_vec(n: usize, seed: u64) -> Arc<AlignedVec> {
        let mut rng = Rng::new(seed);
        let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        Arc::new(AlignedVec::copy_from(&data))
    }

    #[test]
    fn handle_submit_miss_computes_hit_replays_bit_identically() {
        let asy = AsyncDotService::new(cfg(2, 1000), AsyncOptions::default()).unwrap();
        let x = aligned_vec(800, 21);
        let y = aligned_vec(800, 22);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        assert!(a.fresh);
        assert_eq!(a.n, 800);
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        assert_ne!(a.handle, b.handle);
        // Re-registering identical contents is an upsert: same handle.
        let again = asy.register_operand(Arc::clone(&x)).unwrap();
        assert_eq!(again.handle, a.handle);
        assert!(!again.fresh);
        assert_eq!(asy.store_stats().registered, 2);
        assert_eq!(asy.store_stats().reregistered, 1);

        let input = SharedInput::Dot(Arc::clone(&x), Arc::clone(&y));
        let want = asy.service().submit(&input.view()).unwrap();
        let miss = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        let hit = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(miss.value.to_bits(), want.value.to_bits());
        assert_eq!(
            hit.value.to_bits(),
            miss.value.to_bits(),
            "cached result must be bit-identical to the recomputation"
        );
        assert_eq!(hit.path, miss.path);
        assert_eq!(hit.n, miss.n);

        let cs = asy.cache_stats();
        assert_eq!(cs.lookups, 2);
        assert_eq!(cs.hits, 1);
        assert_eq!(cs.misses, 1);
        assert_eq!(cs.hits + cs.misses, cs.lookups, "accounting partition");
        let stats = asy.stats();
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(
            stats.completed,
            stats.enqueued + stats.cache_hits,
            "a hit completes without ever enqueueing"
        );
    }

    #[test]
    fn unknown_handles_fail_typed_and_reuse_after_release_is_collision_free() {
        let asy = AsyncDotService::new(cfg(1, 1000), AsyncOptions::default()).unwrap();
        match asy.submit_handles(0xdead, 0xbeef).unwrap_err() {
            BackendError::UnknownHandle { handle } => {
                assert_eq!(handle, 0xdead, "first unknown handle reported");
            }
            other => panic!("expected UnknownHandle, got {other:?}"),
        }
        let x = aligned_vec(64, 31);
        let y = aligned_vec(64, 32);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        assert!(asy.release_operand(a.handle));
        match asy.submit_handles(a.handle, b.handle).unwrap_err() {
            BackendError::UnknownHandle { handle } => assert_eq!(handle, a.handle),
            other => panic!("expected UnknownHandle, got {other:?}"),
        }
        // Content addressing: the same contents re-register to the same
        // handle, and the handle serves again.
        let re = asy.register_operand(Arc::clone(&x)).unwrap();
        assert_eq!(re.handle, a.handle);
        assert!(re.fresh, "released contents re-register as fresh");
        let input = SharedInput::Dot(Arc::clone(&x), Arc::clone(&y));
        let want = asy.service().submit(&input.view()).unwrap();
        let got = asy.submit_handles(re.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(got.value.to_bits(), want.value.to_bits());
        // Handle submits validate shapes exactly like payload submits.
        let short = asy.register_operand(aligned_vec(32, 33)).unwrap();
        assert!(matches!(
            asy.submit_handles(re.handle, short.handle),
            Err(BackendError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn cache_hits_attribute_to_the_hitting_tenant() {
        let policy = QosPolicy::parse("a:1,b:1").unwrap();
        let asy =
            AsyncDotService::new_with_qos(cfg(2, 1000), AsyncOptions::default(), Some(policy), None)
                .unwrap();
        let x = aligned_vec(512, 51);
        let y = aligned_vec(512, 52);
        let a = asy.register_operand(x).unwrap();
        let b = asy.register_operand(y).unwrap();
        // Tenant 1 computes the miss; tenant 0 rides the cache.
        let miss = asy
            .submit_handles_with_opts(a.handle, b.handle, Instant::now(), None, 1, false)
            .unwrap()
            .wait()
            .unwrap();
        let hit = asy
            .submit_handles_with_opts(a.handle, b.handle, Instant::now(), None, 0, false)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(hit.value.to_bits(), miss.value.to_bits());
        let rows = asy.tenant_stats();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.admitted, 1);
            assert_eq!(row.completed, 1, "hits count as completed work");
        }
        assert_eq!(rows[0].cache_hits, 1, "the hit belongs to tenant 0");
        assert_eq!(rows[1].cache_hits, 0, "the miss computed for tenant 1");
    }

    #[test]
    fn release_while_request_is_in_flight_never_frees_under_the_reader() {
        let asy = AsyncDotService::new(cfg(2, 1000), AsyncOptions::default()).unwrap();
        let x = aligned_vec(4096, 41);
        let y = aligned_vec(4096, 42);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        let input = SharedInput::Dot(Arc::clone(&x), Arc::clone(&y));
        let want = asy.service().submit(&input.view()).unwrap();
        // Submit resolves the handles (the request now owns Arcs to the
        // operands), then release both before the result is awaited: the
        // store drops its references, the in-flight request keeps its own.
        let handle = asy.submit_handles(a.handle, b.handle).unwrap();
        assert!(asy.release_operand(a.handle));
        assert!(asy.release_operand(b.handle));
        assert!(!asy.release_operand(a.handle), "release is idempotent");
        let got = handle.wait().unwrap();
        assert_eq!(
            got.value.to_bits(),
            want.value.to_bits(),
            "released-under-reader request must still compute correctly"
        );
        // The handles themselves are gone for new submissions.
        assert!(matches!(
            asy.submit_handles(a.handle, b.handle),
            Err(BackendError::UnknownHandle { .. })
        ));
        assert_eq!(asy.store_stats().released, 2);
    }

    #[test]
    fn verify_on_hit_full_rate_confirms_clean_hits_bit_for_bit() {
        let mut c = cfg(2, 1000);
        c.verify_hit_rate = 1.0;
        let asy = AsyncDotService::new(c, AsyncOptions::default()).unwrap();
        let a = asy.register_operand(aligned_vec(600, 61)).unwrap();
        let b = asy.register_operand(aligned_vec(600, 62)).unwrap();
        let miss = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        let hit = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        let hit2 = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(hit.value.to_bits(), miss.value.to_bits());
        assert_eq!(hit2.value.to_bits(), miss.value.to_bits());
        let cs = asy.cache_stats();
        assert_eq!(cs.hits, 2);
        assert_eq!(cs.verified, 2, "rate 1.0 must verify every hit");
        assert_eq!(cs.poisoned, 0, "clean entries never count as poisoned");
        assert_eq!(cs.hits + cs.misses, cs.lookups, "accounting partition");
    }

    #[test]
    fn poisoned_cache_entry_is_detected_evicted_and_recomputed() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::none().with(FaultSite::CachePoison, 1);
        let injector = crate::serve::faults::FaultInjector::new(plan);
        let mut c = cfg(2, 1000);
        c.verify_hit_rate = 1.0;
        let asy =
            AsyncDotService::new_with_faults(c, AsyncOptions::default(), Some(Arc::clone(&injector)))
                .unwrap();
        let x = aligned_vec(700, 71);
        let y = aligned_vec(700, 72);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        let input = SharedInput::Dot(Arc::clone(&x), Arc::clone(&y));
        let want = asy.service().submit(&input.view()).unwrap();
        // The miss computes the right answer, then the armed trigger flips
        // the memoized bits behind it.
        let miss = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(miss.value.to_bits(), want.value.to_bits());
        assert_eq!(injector.fired(FaultSite::CachePoison), 1);
        // The next submit samples the poisoned hit: the bit-compare fails,
        // the entry is evicted, and the request recomputes — the corrupt
        // value is never delivered.
        let recomputed = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(
            recomputed.value.to_bits(),
            want.value.to_bits(),
            "a poisoned entry must never reach a caller"
        );
        let cs = asy.cache_stats();
        assert_eq!(cs.poisoned, 1, "the poisoned entry was detected exactly once");
        assert_eq!(cs.hits + cs.misses, cs.lookups, "accounting partition");
        // The re-memoized entry now verifies clean.
        let clean = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(clean.value.to_bits(), want.value.to_bits());
        assert!(asy.cache_stats().verified >= 1);
    }

    #[test]
    fn corrupted_operand_is_quarantined_typed_and_recovers_on_reregister() {
        use super::super::faults::FaultPlan;
        let plan = FaultPlan::none().with(FaultSite::StoreBitFlip, 1);
        let injector = crate::serve::faults::FaultInjector::new(plan);
        let asy = AsyncDotService::new_with_faults(
            cfg(2, 1000),
            AsyncOptions::default(),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        asy.store().set_verify_on_lookup(true);
        let x = aligned_vec(500, 81);
        let y = aligned_vec(500, 82);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        // The armed trigger flips a bit in operand `a` at resolution; the
        // verified lookup must detect it and fail typed.
        match asy.submit_handles(a.handle, b.handle).unwrap_err() {
            BackendError::CorruptOperand { handle } => assert_eq!(handle, a.handle),
            other => panic!("expected CorruptOperand, got {other:?}"),
        }
        assert_eq!(injector.fired(FaultSite::StoreBitFlip), 1);
        assert_eq!(asy.store_stats().scrub_quarantined, 1);
        // The quarantined handle is gone — subsequent submits see the
        // unknown-handle error, never the corrupt bytes.
        assert!(matches!(
            asy.submit_handles(a.handle, b.handle),
            Err(BackendError::UnknownHandle { .. })
        ));
        // Re-registering the clean contents recovers the same handle and
        // the request completes bit-identically to the sync path.
        let re = asy.register_operand(Arc::clone(&x)).unwrap();
        assert_eq!(re.handle, a.handle);
        let input = SharedInput::Dot(Arc::clone(&x), Arc::clone(&y));
        let want = asy.service().submit(&input.view()).unwrap();
        let got = asy.submit_handles(re.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }

    #[test]
    fn err_bound_is_present_exactly_when_requested_and_certifies_the_value() {
        let asy = AsyncDotService::new(cfg(2, 1000), AsyncOptions::default()).unwrap();
        let input = shared_dot(900, 95);
        let want = asy.service().err_bound_for(&input.view());
        // Opt-in: the bound rides the response.
        let with = asy
            .submit_with_opts(input.clone(), Instant::now(), None, 0, true)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(with.err_bound, Some(want), "bound matches the service's");
        assert!(want > 0.0 && want.is_finite());
        // Default: absent, leaving the response identical to the old shape.
        let without = asy
            .submit_with_opts(input.clone(), Instant::now(), None, 0, false)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(without.err_bound, None);
        assert_eq!(with.value.to_bits(), without.value.to_bits());

        // Handle path: both the computing miss and the cache hit certify.
        let x = aligned_vec(400, 96);
        let y = aligned_vec(400, 97);
        let a = asy.register_operand(Arc::clone(&x)).unwrap();
        let b = asy.register_operand(Arc::clone(&y)).unwrap();
        let miss = asy
            .submit_handles_with_opts(a.handle, b.handle, Instant::now(), None, 0, true)
            .unwrap()
            .wait()
            .unwrap();
        let hit = asy
            .submit_handles_with_opts(a.handle, b.handle, Instant::now(), None, 0, true)
            .unwrap()
            .wait()
            .unwrap();
        let handle_input = SharedInput::Dot(x, y);
        let hb = asy.service().err_bound_for(&handle_input.view());
        assert_eq!(miss.err_bound, Some(hb));
        assert_eq!(hit.err_bound, Some(hb), "a hit certifies like a miss");
        assert!(hb > 0.0 && hb.is_finite());
    }
}
