//! Request classification and dispatch planning for the serving layer.
//!
//! The scheduler is deliberately *pure*: given a batch of requests and the
//! service's shard threshold it decides, per request, whether the request
//! is **fused** (executed whole by one worker, many requests per dispatch)
//! or **sharded** (split across all workers via the pool partition). The
//! decision depends only on the request's length — never on what else is
//! in the batch — which is what makes the batched results bit-identical to
//! the unbatched single-request path: scheduling changes *where* a request
//! runs, never *how*.

use crate::runtime::backend::KernelInput;

/// Which execution path served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecPath {
    /// Executed whole by a single worker inside a fused multi-request
    /// dispatch (or inline, for a lone small request).
    Fused,
    /// Partitioned across all workers and combined by the deterministic
    /// compensated tree reduction.
    Sharded,
}

impl ExecPath {
    /// The label bench artifacts and wire stats record for this path.
    pub fn label(self) -> &'static str {
        match self {
            ExecPath::Fused => "fused",
            ExecPath::Sharded => "sharded",
        }
    }
}

/// The scheduling decision for one batch: request indices routed to the
/// fused dispatch and to individual sharding, each in arrival order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DispatchPlan {
    /// Indices of requests executed whole inside one fused dispatch.
    pub fused: Vec<usize>,
    /// Indices of requests sharded across the pool, run one after another.
    pub sharded: Vec<usize>,
}

impl DispatchPlan {
    /// Total number of planned requests.
    pub fn len(&self) -> usize {
        self.fused.len() + self.sharded.len()
    }

    /// `true` when the plan routes no requests at all.
    pub fn is_empty(&self) -> bool {
        self.fused.is_empty() && self.sharded.is_empty()
    }
}

/// The size-threshold batch scheduler (see the module docs). Holds only the
/// crossover; the owning [`DotService`](crate::serve::DotService) supplies
/// the pool and kernels.
#[derive(Clone, Copy, Debug)]
pub struct BatchScheduler {
    shard_threshold: usize,
}

impl BatchScheduler {
    /// A scheduler that shards requests of at least `shard_threshold`
    /// updates.
    pub fn new(shard_threshold: usize) -> Self {
        Self { shard_threshold }
    }

    /// The crossover this scheduler classifies with.
    pub fn shard_threshold(&self) -> usize {
        self.shard_threshold
    }

    /// Does a request of `n` updates take the sharded path? The boundary is
    /// inclusive: `n >= threshold` shards, everything below fuses.
    pub fn shards(&self, n: usize) -> bool {
        n >= self.shard_threshold
    }

    /// The path a request of `n` updates takes.
    pub fn path_for(&self, n: usize) -> ExecPath {
        if self.shards(n) {
            ExecPath::Sharded
        } else {
            ExecPath::Fused
        }
    }

    /// Split a batch into the fused and sharded index sets, preserving
    /// arrival order within each set.
    pub fn plan(&self, inputs: &[KernelInput<'_>]) -> DispatchPlan {
        self.plan_lens(inputs.iter().map(KernelInput::updates))
    }

    /// [`Self::plan`] over request lengths alone — what the async
    /// dispatcher uses on a drained arrival batch (it holds owned
    /// requests, not borrowed [`KernelInput`]s). The classification is the
    /// same function of `n` either way, which is half of the async == sync
    /// bit-parity argument.
    pub fn plan_lens(&self, lens: impl IntoIterator<Item = usize>) -> DispatchPlan {
        let mut plan = DispatchPlan::default();
        for (i, n) in lens.into_iter().enumerate() {
            if self.shards(n) {
                plan.sharded.push(i);
            } else {
                plan.fused.push(i);
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_is_inclusive() {
        let s = BatchScheduler::new(100);
        assert_eq!(s.path_for(99), ExecPath::Fused);
        assert_eq!(s.path_for(100), ExecPath::Sharded);
        assert_eq!(s.path_for(101), ExecPath::Sharded);
        assert!(!s.shards(0));
    }

    #[test]
    fn zero_threshold_shards_everything() {
        let s = BatchScheduler::new(0);
        assert_eq!(s.path_for(0), ExecPath::Sharded);
        assert_eq!(s.path_for(1), ExecPath::Sharded);
    }

    #[test]
    fn plan_preserves_arrival_order() {
        let a = vec![1.0; 8];
        let b = vec![2.0; 200];
        let inputs = [
            KernelInput::Sum(&a),
            KernelInput::Sum(&b),
            KernelInput::Dot(&a, &a),
            KernelInput::Dot(&b, &b),
            KernelInput::Sum(&a),
        ];
        let plan = BatchScheduler::new(100).plan(&inputs);
        assert_eq!(plan.fused, vec![0, 2, 4]);
        assert_eq!(plan.sharded, vec![1, 3]);
        assert_eq!(plan.len(), 5);
        assert!(!plan.is_empty());
    }

    #[test]
    fn plan_lens_matches_plan() {
        let a = vec![1.0; 8];
        let b = vec![2.0; 200];
        let inputs = [
            KernelInput::Sum(&a),
            KernelInput::Dot(&b, &b),
            KernelInput::Sum(&b),
            KernelInput::Dot(&a, &a),
        ];
        let s = BatchScheduler::new(100);
        let by_input = s.plan(&inputs);
        let by_len = s.plan_lens(inputs.iter().map(|i| i.updates()));
        assert_eq!(by_input, by_len);
    }
}
