//! The resident operand store and the content-addressed result cache —
//! the serving layer's answer to the paper's central observation turned
//! around: once the Kahan-compensated kernel is memory-bound, compensation
//! is free but *data traffic is not*. A read-heavy workload that re-sends
//! the same operand vectors pays O(n) wire bytes and O(n) kernel traffic
//! per request for answers the service has already computed. This module
//! removes both:
//!
//! * [`OperandStore`] — clients register a vector once (the wire REGISTER
//!   frame, PROTOCOL.md §3.8); the server hashes its *contents*
//!   (SHA-256 of the encoded little-endian IEEE-754 bytes) into a 64-bit
//!   handle and keeps the operand resident in the same 64-byte-aligned
//!   first-touch arena in-process operands use. Subsequent requests submit
//!   by `(handle_a, handle_b)` — 16 payload bytes instead of 16·n.
//! * [`ResultCache`] — completed `(operand-pair, kernel, T)` results are
//!   memoized by handle pair. Handles are content hashes, so a cache entry
//!   can never go stale: the same handle pair *is* the same bits in, and
//!   at fixed `T` the deterministic kernel produces the same bits out.
//!   A hit replays the stored IEEE-754 bit pattern and the original
//!   execution path — bit-identical to recomputation by construction, and
//!   property-pinned in `tests/properties.rs` (including across the
//!   socket).
//!
//! **Content addressing.** The handle is the first 8 bytes of the SHA-256
//! digest, little-endian. Registering the same contents twice is an upsert
//! that returns the same handle (`fresh == false` the second time); the
//! full 32-byte digest is kept per entry, and the astronomically
//! improbable truncated-handle collision (same 64-bit prefix, different
//! digest) is *rejected* rather than silently overwritten, so one handle
//! never aliases two payloads. This is what makes the result cache safe
//! without any invalidation protocol: RELEASE and LRU eviction remove
//! residency, never correctness — a re-registered operand gets the same
//! handle back and every cached result keyed by it is still exact.
//!
//! **Release under in-flight readers.** The store hands out `Arc` clones
//! of the operand buffer and holds exactly one `Arc` itself. RELEASE (or
//! eviction) drops the *store's* reference only; a request already
//! resolved against the handle keeps the arena slot alive through its own
//! clone until it retires. Freeing the slot under a reader is therefore
//! structurally impossible, not merely avoided — pinned by a regression
//! test in `tests/properties.rs`.
//!
//! **Bounds.** Both structures are capacity-bounded with
//! least-recently-used eviction (the store by resident bytes, the cache by
//! entry count) and expose monotonic counters whose partition invariants
//! (`hits + misses == lookups`) are hard-gated by
//! `tools/validate_bench.py` from the `zipf` block of
//! `BENCH_serving.json`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::runtime::arena::AlignedVec;

use super::scheduler::ExecPath;

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4), dependency-free.
// ---------------------------------------------------------------------------

/// The 64 SHA-256 round constants: fractional parts of the cube roots of
/// the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Streaming SHA-256 state: feed bytes with [`Sha256::update`], finish
/// with [`Sha256::finalize`]. Streaming (rather than one-shot over a
/// concatenated buffer) lets the store hash an operand's encoded bytes
/// without materializing a second copy of the vector.
struct Sha256 {
    h: [u32; 8],
    block: [u8; 64],
    block_len: usize,
    total_len: u64,
}

impl Sha256 {
    fn new() -> Self {
        Self {
            // Fractional parts of the square roots of the first 8 primes.
            h: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            block: [0u8; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    fn compress(&mut self) {
        let mut w = [0u32; 64];
        for (i, chunk) in self.block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.h.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }

    fn update(&mut self, mut data: &[u8]) {
        self.total_len += data.len() as u64;
        while !data.is_empty() {
            let take = (64 - self.block_len).min(data.len());
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                self.compress();
                self.block_len = 0;
            }
        }
    }

    fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len * 8;
        self.update(&[0x80]);
        while self.block_len != 56 {
            self.update(&[0x00]);
        }
        // `update` would double-count the length bytes into total_len, but
        // total_len was already captured in bit_len above, so feed the
        // trailer directly through the block buffer.
        self.block[56..64].copy_from_slice(&bit_len.to_be_bytes());
        self.block_len = 64;
        self.compress();
        let mut out = [0u8; 32];
        for (chunk, word) in out.chunks_exact_mut(4).zip(self.h) {
            chunk.copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One-shot SHA-256 of a byte slice (FIPS 180-4). Exposed for tests and
/// for anyone who needs to predict a handle client-side.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut s = Sha256::new();
    s.update(data);
    s.finalize()
}

/// The content digest of an operand vector: SHA-256 over its encoded wire
/// bytes — each element's IEEE-754 bit pattern, little-endian, in order
/// (exactly the bytes a REGISTER payload carries after the count,
/// PROTOCOL.md §3.8). Two vectors hash equal iff they are bit-identical.
pub fn operand_digest(data: &[f64]) -> [u8; 32] {
    let mut s = Sha256::new();
    for v in data {
        s.update(&v.to_bits().to_le_bytes());
    }
    s.finalize()
}

/// The 64-bit resident-operand handle derived from a content digest: the
/// first 8 digest bytes, little-endian (PROTOCOL.md §3.8).
pub fn handle_of(digest: &[u8; 32]) -> u64 {
    u64::from_le_bytes([
        digest[0], digest[1], digest[2], digest[3], digest[4], digest[5], digest[6], digest[7],
    ])
}

// ---------------------------------------------------------------------------
// Operand store
// ---------------------------------------------------------------------------

/// Why a registration was refused ([`OperandStore::register`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// The operand alone exceeds the store's byte capacity — no amount of
    /// eviction can make it resident. Maps to the wire STORE_FULL error
    /// (PROTOCOL.md §4.13).
    Full {
        /// Bytes the operand would occupy.
        requested: usize,
        /// The store's configured capacity in bytes.
        capacity: usize,
    },
    /// A different payload already owns this truncated handle (same first
    /// 8 digest bytes, different full digest). Rejected so a handle never
    /// aliases two payloads; with 64-bit handles this is effectively
    /// unreachable, but the check is what makes the no-alias guarantee a
    /// certainty instead of a probability.
    Collision {
        /// The contested handle value.
        handle: u64,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Full {
                requested,
                capacity,
            } => write!(
                f,
                "operand of {requested} bytes exceeds the store capacity of {capacity} bytes"
            ),
            StoreError::Collision { handle } => {
                write!(f, "truncated-digest collision on handle {handle:#018x}")
            }
        }
    }
}

/// What [`OperandStore::register`] observed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegisterOutcome {
    /// The content-derived handle (PROTOCOL.md §3.8).
    pub handle: u64,
    /// Element count of the registered operand.
    pub n: usize,
    /// `true` if the contents were not resident before this call; `false`
    /// for the upsert of already-resident contents (same handle returned).
    pub fresh: bool,
}

/// Monotonic operand-store counters plus the current residency snapshot
/// ([`OperandStore::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Operands currently resident.
    pub entries: u64,
    /// Bytes currently resident (sum of 8·n over entries).
    pub resident_bytes: u64,
    /// Fresh registrations (new contents made resident).
    pub registered: u64,
    /// Upserts: registrations whose contents were already resident.
    pub reregistered: u64,
    /// Explicit releases that found and removed an entry.
    pub released: u64,
    /// Entries removed by capacity-pressure LRU eviction.
    pub evictions: u64,
    /// Handle lookups ([`OperandStore::lookup`] calls).
    pub lookups: u64,
    /// Lookups that found no resident entry (UNKNOWN_HANDLE on the wire).
    pub lookup_misses: u64,
    /// Digest re-checks that matched the registration digest — on-demand
    /// ([`OperandStore::verify`]) and background ([`OperandStore::scrub_all`])
    /// scrubs alike.
    pub scrub_verified: u64,
    /// Entries quarantined on digest mismatch: removed from the map, never
    /// served again (wire CORRUPT_OPERAND). Outstanding reader `Arc`s keep
    /// the old buffer alive, exactly as for release.
    pub scrub_quarantined: u64,
    /// Full [`OperandStore::scrub_all`] sweeps completed.
    pub scrub_passes: u64,
}

/// What a digest re-check observed ([`OperandStore::verify`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScrubOutcome {
    /// The resident bytes still hash to the registration digest.
    Clean,
    /// The bytes no longer match: the entry was removed (quarantined) and
    /// will never be served. The wire CORRUPT_OPERAND condition.
    Quarantined,
    /// The handle was not resident (nothing to check).
    Absent,
}

struct StoreEntry {
    digest: [u8; 32],
    data: Arc<AlignedVec>,
    /// LRU clock stamp: larger is more recently used.
    last_used: u64,
}

struct StoreInner {
    entries: HashMap<u64, StoreEntry>,
    resident_bytes: usize,
    clock: u64,
    registered: u64,
    reregistered: u64,
    released: u64,
    evictions: u64,
    lookups: u64,
    lookup_misses: u64,
    scrub_verified: u64,
    scrub_quarantined: u64,
    scrub_passes: u64,
}

/// The arena-backed resident operand store (module docs). Thread-safe:
/// one mutex guards the handle map — registration and lookup are O(1)
/// hash operations plus (for registration) the content hash itself, which
/// is computed *outside* the lock.
pub struct OperandStore {
    capacity_bytes: usize,
    /// When set, every handle resolution re-hashes the resident bytes
    /// against the registration digest before serving them (the on-demand
    /// scrub, [`OperandStore::lookup_verified`]). Off by default: the
    /// verify-off path is bit- and counter-identical to a store without
    /// the scrubber.
    verify_on_lookup: AtomicBool,
    inner: Mutex<StoreInner>,
}

/// Default store capacity: 256 MiB of resident operands — two full
/// default-mixture catalogs with room to spare, small enough to bound a
/// long-lived server's footprint.
pub const STORE_DEFAULT_CAPACITY_BYTES: usize = 256 << 20;

impl OperandStore {
    /// An empty store bounded at `capacity_bytes` of resident operand data
    /// (clamped to at least one cache line, 64 bytes).
    pub fn new(capacity_bytes: usize) -> Self {
        Self {
            capacity_bytes: capacity_bytes.max(64),
            verify_on_lookup: AtomicBool::new(false),
            inner: Mutex::new(StoreInner {
                entries: HashMap::new(),
                resident_bytes: 0,
                clock: 0,
                registered: 0,
                reregistered: 0,
                released: 0,
                evictions: 0,
                lookups: 0,
                lookup_misses: 0,
                scrub_verified: 0,
                scrub_quarantined: 0,
                scrub_passes: 0,
            }),
        }
    }

    /// Poison-tolerant inner access (same policy as the queue mutex: a
    /// panicking peer leaves the map structurally intact).
    fn lock(&self) -> MutexGuard<'_, StoreInner> {
        self.inner
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// The configured byte capacity.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Register an operand: hash its contents, upsert it under the derived
    /// handle, and evict least-recently-used entries if the insert pushed
    /// residency past the capacity (the just-inserted entry is never the
    /// eviction victim). The store keeps one `Arc` clone; the caller keeps
    /// its own, so registration never copies the vector.
    pub fn register(&self, data: Arc<AlignedVec>) -> Result<RegisterOutcome, StoreError> {
        let digest = operand_digest(&data);
        let handle = handle_of(&digest);
        let n = data.len();
        let bytes = 8 * n;
        if bytes > self.capacity_bytes {
            return Err(StoreError::Full {
                requested: bytes,
                capacity: self.capacity_bytes,
            });
        }
        let mut s = self.lock();
        s.clock += 1;
        let stamp = s.clock;
        if let Some(entry) = s.entries.get_mut(&handle) {
            if entry.digest != digest {
                return Err(StoreError::Collision { handle });
            }
            entry.last_used = stamp;
            s.reregistered += 1;
            return Ok(RegisterOutcome {
                handle,
                n,
                fresh: false,
            });
        }
        s.entries.insert(
            handle,
            StoreEntry {
                digest,
                data,
                last_used: stamp,
            },
        );
        s.resident_bytes += bytes;
        s.registered += 1;
        while s.resident_bytes > self.capacity_bytes {
            let victim = s
                .entries
                .iter()
                .filter(|&(&h, _)| h != handle)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&h, _)| h)
                .expect("over-capacity store must hold an evictable entry");
            let gone = s.entries.remove(&victim).expect("victim is resident");
            s.resident_bytes -= 8 * gone.data.len();
            s.evictions += 1;
        }
        Ok(RegisterOutcome {
            handle,
            n,
            fresh: true,
        })
    }

    /// Resolve a handle to its resident operand, bumping its LRU stamp.
    /// The returned `Arc` keeps the buffer alive independently of the
    /// store — a later release or eviction cannot free it under the
    /// caller (module docs). `None` is the wire UNKNOWN_HANDLE condition.
    pub fn lookup(&self, handle: u64) -> Option<Arc<AlignedVec>> {
        let mut s = self.lock();
        s.lookups += 1;
        s.clock += 1;
        let stamp = s.clock;
        match s.entries.get_mut(&handle) {
            Some(entry) => {
                entry.last_used = stamp;
                Some(Arc::clone(&entry.data))
            }
            None => {
                s.lookup_misses += 1;
                None
            }
        }
    }

    /// Drop the store's reference to a handle. Idempotent: `true` if an
    /// entry was resident and removed, `false` if the handle was unknown
    /// (already released, evicted, or never registered). In-flight
    /// requests holding `Arc` clones are unaffected either way.
    pub fn release(&self, handle: u64) -> bool {
        let mut s = self.lock();
        match s.entries.remove(&handle) {
            Some(entry) => {
                s.resident_bytes -= 8 * entry.data.len();
                s.released += 1;
                true
            }
            None => false,
        }
    }

    /// Whether a handle is currently resident (no LRU bump, no counters).
    pub fn contains(&self, handle: u64) -> bool {
        self.lock().entries.contains_key(&handle)
    }

    /// Enable or disable the on-demand scrub performed by
    /// [`OperandStore::lookup_verified`]. Runtime-togglable so a server
    /// can turn verification on under suspicion without a restart.
    pub fn set_verify_on_lookup(&self, on: bool) {
        self.verify_on_lookup.store(on, Ordering::Relaxed);
    }

    /// Whether lookups currently re-verify the resident bytes.
    pub fn verify_on_lookup(&self) -> bool {
        self.verify_on_lookup.load(Ordering::Relaxed)
    }

    /// Re-hash one resident operand against its registration digest. The
    /// SHA-256 pass runs *outside* the lock (an `Arc` clone pins the
    /// buffer), so scrubbing a large operand never stalls registration or
    /// lookup; the verdict is applied under the lock only if the entry
    /// still holds the same buffer, else the check re-runs. A mismatch
    /// quarantines: the entry is removed from the map — never served
    /// again, the wire CORRUPT_OPERAND condition — while outstanding
    /// reader `Arc`s keep the old buffer alive exactly as for release.
    /// Scrubs never bump LRU stamps: verification must not perturb
    /// eviction order.
    pub fn verify(&self, handle: u64) -> ScrubOutcome {
        loop {
            let (data, digest) = {
                let s = self.lock();
                match s.entries.get(&handle) {
                    Some(entry) => (Arc::clone(&entry.data), entry.digest),
                    None => return ScrubOutcome::Absent,
                }
            };
            let clean = operand_digest(&data) == digest;
            let mut s = self.lock();
            match s.entries.get(&handle) {
                Some(entry) if Arc::ptr_eq(&entry.data, &data) => {
                    if clean {
                        s.scrub_verified += 1;
                        return ScrubOutcome::Clean;
                    }
                    let gone = s.entries.remove(&handle).expect("checked resident");
                    s.resident_bytes -= 8 * gone.data.len();
                    s.scrub_quarantined += 1;
                    return ScrubOutcome::Quarantined;
                }
                // The buffer was swapped while the hash ran (re-register
                // after release, or a chaos corruption): the verdict is
                // stale — verify the current buffer instead.
                Some(_) => continue,
                None => return ScrubOutcome::Absent,
            }
        }
    }

    /// One background scrub pass: verify every resident handle, returning
    /// `(clean, quarantined)` counts. Entries released or evicted while
    /// the pass runs are simply skipped. Bumps `scrub_passes`.
    pub fn scrub_all(&self) -> (u64, u64) {
        let handles: Vec<u64> = self.lock().entries.keys().copied().collect();
        let mut clean = 0u64;
        let mut quarantined = 0u64;
        for handle in handles {
            match self.verify(handle) {
                ScrubOutcome::Clean => clean += 1,
                ScrubOutcome::Quarantined => quarantined += 1,
                ScrubOutcome::Absent => {}
            }
        }
        self.lock().scrub_passes += 1;
        (clean, quarantined)
    }

    /// Resolve a handle with the on-demand scrub applied when enabled
    /// ([`OperandStore::set_verify_on_lookup`]): `Err(handle)` means the
    /// resident bytes failed verification and the entry was quarantined —
    /// the wire CORRUPT_OPERAND condition; `Ok(None)` is the ordinary
    /// UNKNOWN_HANDLE miss. With verification disabled this is exactly
    /// [`OperandStore::lookup`]. A quarantined resolution counts as
    /// neither lookup nor miss: it is a third, separately-counted outcome
    /// (`scrub_quarantined`).
    pub fn lookup_verified(&self, handle: u64) -> Result<Option<Arc<AlignedVec>>, u64> {
        if self.verify_on_lookup() && self.verify(handle) == ScrubOutcome::Quarantined {
            return Err(handle);
        }
        Ok(self.lookup(handle))
    }

    /// Chaos hook (`store_bit_flip` fault site): replace a resident
    /// operand's buffer with a copy whose first element has its low
    /// mantissa bit flipped, leaving the registration digest untouched —
    /// the next scrub of this handle *must* quarantine it. Readers that
    /// resolved before the flip keep their clean snapshot (their `Arc`
    /// points at the original buffer). Returns whether the handle was
    /// resident and non-empty.
    pub fn corrupt_resident(&self, handle: u64) -> bool {
        let mut s = self.lock();
        match s.entries.get_mut(&handle) {
            Some(entry) if !entry.data.is_empty() => {
                let mut flipped: Vec<f64> = entry.data.iter().copied().collect();
                flipped[0] = f64::from_bits(flipped[0].to_bits() ^ 1);
                entry.data = Arc::new(AlignedVec::copy_from(&flipped));
                true
            }
            _ => false,
        }
    }

    /// Counter + residency snapshot.
    pub fn stats(&self) -> StoreStats {
        let s = self.lock();
        StoreStats {
            entries: s.entries.len() as u64,
            resident_bytes: s.resident_bytes as u64,
            registered: s.registered,
            reregistered: s.reregistered,
            released: s.released,
            evictions: s.evictions,
            lookups: s.lookups,
            lookup_misses: s.lookup_misses,
            scrub_verified: s.scrub_verified,
            scrub_quarantined: s.scrub_quarantined,
            scrub_passes: s.scrub_passes,
        }
    }
}

impl std::fmt::Debug for OperandStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("OperandStore")
            .field("capacity_bytes", &self.capacity_bytes)
            .field("entries", &s.entries)
            .field("resident_bytes", &s.resident_bytes)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Result cache
// ---------------------------------------------------------------------------

/// One memoized result: the answer's IEEE-754 bit pattern, the update
/// count, and the execution path the original computation took. A cache
/// hit replays all three, so the response frame is byte-identical to the
/// recomputation it stands in for (PROTOCOL.md §3.5 — the path byte
/// included).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CachedResult {
    /// `f64::to_bits` of the dot value.
    pub bits: u64,
    /// Element count of the operands.
    pub n: usize,
    /// The path the original execution took (fused or sharded).
    pub path: ExecPath,
}

/// Monotonic result-cache counters ([`ResultCache::stats`]). The
/// partition `hits + misses == lookups` is hard-gated by
/// `tools/validate_bench.py`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entries currently memoized.
    pub entries: u64,
    /// Configured entry capacity.
    pub capacity: u64,
    /// Probe count ([`ResultCache::get`] calls).
    pub lookups: u64,
    /// Probes that found a memoized result.
    pub hits: u64,
    /// Probes that found nothing (`hits + misses == lookups`).
    pub misses: u64,
    /// Results inserted after a computed miss.
    pub insertions: u64,
    /// Entries removed by capacity-pressure LRU eviction.
    pub evictions: u64,
    /// Sampled hits whose recomputation bit-matched the memoized value
    /// (the verify-on-hit policy, `ServeConfig::verify_hit_rate`).
    pub verified: u64,
    /// Sampled hits whose recomputation *disagreed*: the entry was
    /// evicted via [`ResultCache::evict_poisoned`] and the request fell
    /// through to recompute. Not counted under `evictions` (which tracks
    /// capacity pressure only).
    pub poisoned: u64,
}

struct CacheEntry {
    result: CachedResult,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<(u64, u64), CacheEntry>,
    clock: u64,
    lookups: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    verified: u64,
    poisoned: u64,
}

/// The content-addressed result cache (module docs), keyed by the ordered
/// operand-handle pair. The kernel variant and the thread count `T` are
/// fixed per service — a service is one `(kernel, T)` context — so they
/// are part of the cache's identity, not its key; a config change builds
/// a fresh service and with it a fresh cache.
pub struct ResultCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
}

/// Default result-cache bound: 4096 memoized pairs — far above any bench
/// catalog, small enough that a hostile client cannot balloon the server.
pub const CACHE_DEFAULT_ENTRIES: usize = 4096;

impl ResultCache {
    /// An empty cache bounded at `capacity` entries (clamped to >= 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                clock: 0,
                lookups: 0,
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
                verified: 0,
                poisoned: 0,
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// The configured entry capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Probe the cache, bumping the entry's LRU stamp on a hit. Counts
    /// exactly one lookup and exactly one of hit/miss.
    pub fn get(&self, key: (u64, u64)) -> Option<CachedResult> {
        let mut s = self.lock();
        s.lookups += 1;
        s.clock += 1;
        let stamp = s.clock;
        match s.map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = stamp;
                s.hits += 1;
                Some(entry.result)
            }
            None => {
                s.misses += 1;
                None
            }
        }
    }

    /// Memoize a computed result, evicting the least-recently-used entry
    /// if the insert exceeded the capacity. Upserting an existing key
    /// refreshes its LRU stamp; content addressing guarantees the value
    /// is identical, so which writer wins is unobservable.
    pub fn insert(&self, key: (u64, u64), result: CachedResult) {
        let mut s = self.lock();
        s.clock += 1;
        let stamp = s.clock;
        let fresh = s
            .map
            .insert(
                key,
                CacheEntry {
                    result,
                    last_used: stamp,
                },
            )
            .is_none();
        if fresh {
            s.insertions += 1;
        }
        while s.map.len() > self.capacity {
            let victim = s
                .map
                .iter()
                .filter(|&(&k, _)| k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
                .expect("over-capacity cache must hold an evictable entry");
            s.map.remove(&victim);
            s.evictions += 1;
        }
    }

    /// Record one verify-on-hit sample whose recomputation bit-matched
    /// the memoized value.
    pub fn note_verified(&self) {
        self.lock().verified += 1;
    }

    /// Evict an entry whose verify-on-hit recomputation disagreed with
    /// the memoized bits. Counts under `poisoned`, not `evictions` (which
    /// tracks capacity pressure only). Returns whether the key was
    /// present. The hit that exposed the poisoning was already counted as
    /// a hit; the caller falls through to recompute, so the partition
    /// `hits + misses == lookups` is preserved.
    pub fn evict_poisoned(&self, key: (u64, u64)) -> bool {
        let mut s = self.lock();
        if s.map.remove(&key).is_some() {
            s.poisoned += 1;
            true
        } else {
            false
        }
    }

    /// Chaos hook (`cache_poison` fault site): flip the low bit of a
    /// memoized result's IEEE-754 pattern in place, so the next sampled
    /// hit on this key *must* fail its bit-compare. Returns whether the
    /// key was present.
    pub fn poison(&self, key: (u64, u64)) -> bool {
        let mut s = self.lock();
        match s.map.get_mut(&key) {
            Some(entry) => {
                entry.result.bits ^= 1;
                true
            }
            None => false,
        }
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> CacheStats {
        let s = self.lock();
        CacheStats {
            entries: s.map.len() as u64,
            capacity: self.capacity as u64,
            lookups: s.lookups,
            hits: s.hits,
            misses: s.misses,
            insertions: s.insertions,
            evictions: s.evictions,
            verified: s.verified,
            poisoned: s.poisoned,
        }
    }
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("ResultCache")
            .field("capacity", &self.capacity)
            .field("entries", &s.entries)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(digest: &[u8; 32]) -> String {
        digest.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn aligned(values: &[f64]) -> Arc<AlignedVec> {
        Arc::new(AlignedVec::copy_from(values))
    }

    #[test]
    fn sha256_matches_fips_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // Multi-block + padding-boundary lengths (55/56/64 bytes) stress
        // the streaming finalize path.
        for len in [55usize, 56, 63, 64, 65, 200] {
            let data = vec![0x61u8; len];
            let mut s = Sha256::new();
            for b in &data {
                s.update(std::slice::from_ref(b));
            }
            assert_eq!(s.finalize(), sha256(&data), "len={len}");
        }
    }

    #[test]
    fn operand_digest_is_bitwise_content_addressing() {
        let a = operand_digest(&[1.0, -2.5, 3.75]);
        let b = operand_digest(&[1.0, -2.5, 3.75]);
        assert_eq!(a, b);
        // 0.0 and -0.0 compare equal as floats but differ in bits: the
        // digest must see the bits (the whole point of bit-parity).
        assert_ne!(operand_digest(&[0.0]), operand_digest(&[-0.0]));
        // Matches hashing the encoded little-endian bytes directly.
        let values = [1.5f64, f64::MIN_POSITIVE, -1e300];
        let mut bytes = Vec::new();
        for v in &values {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        assert_eq!(operand_digest(&values), sha256(&bytes));
    }

    #[test]
    fn register_is_an_upsert_returning_the_same_handle() {
        let store = OperandStore::new(1 << 20);
        let first = store.register(aligned(&[1.0, 2.0, 3.0])).unwrap();
        assert!(first.fresh);
        assert_eq!(first.n, 3);
        let again = store.register(aligned(&[1.0, 2.0, 3.0])).unwrap();
        assert!(!again.fresh);
        assert_eq!(again.handle, first.handle);
        let stats = store.stats();
        assert_eq!(stats.registered, 1);
        assert_eq!(stats.reregistered, 1);
        assert_eq!(stats.entries, 1);
        assert_eq!(stats.resident_bytes, 24);
    }

    #[test]
    fn lookup_counts_hits_and_misses_and_release_is_idempotent() {
        let store = OperandStore::new(1 << 20);
        let out = store.register(aligned(&[4.0, 5.0])).unwrap();
        assert!(store.lookup(out.handle).is_some());
        assert!(store.lookup(0xDEAD_BEEF).is_none());
        assert!(store.release(out.handle));
        assert!(!store.release(out.handle), "second release finds nothing");
        assert!(store.lookup(out.handle).is_none());
        let stats = store.stats();
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.lookup_misses, 2);
        assert_eq!(stats.released, 1);
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.resident_bytes, 0);
    }

    #[test]
    fn released_entries_stay_alive_through_outstanding_arcs() {
        // The RELEASE-under-reader regression (ISSUE 9 fix): the store
        // drops only its own Arc; a reader's clone keeps the arena slot
        // valid.
        let store = OperandStore::new(1 << 20);
        let values: Vec<f64> = (0..1000).map(|i| i as f64 * 0.5).collect();
        let out = store.register(aligned(&values)).unwrap();
        let held = store.lookup(out.handle).expect("resident");
        assert!(store.release(out.handle));
        for (i, v) in held.iter().enumerate() {
            assert_eq!(v.to_bits(), (i as f64 * 0.5).to_bits());
        }
    }

    #[test]
    fn store_eviction_is_lru_and_never_evicts_the_newcomer() {
        // Capacity for exactly two 8-element operands (128 bytes).
        let store = OperandStore::new(128);
        let a = store
            .register(aligned(&[1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]))
            .unwrap();
        let b = store
            .register(aligned(&[2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0]))
            .unwrap();
        // Touch `a` so `b` becomes the LRU victim.
        assert!(store.lookup(a.handle).is_some());
        let c = store
            .register(aligned(&[3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0]))
            .unwrap();
        assert!(store.contains(a.handle), "recently-used survives");
        assert!(!store.contains(b.handle), "LRU entry evicted");
        assert!(store.contains(c.handle), "newcomer never evicted");
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.stats().resident_bytes, 128);
    }

    #[test]
    fn oversized_operand_is_refused_with_store_full() {
        let store = OperandStore::new(64);
        let err = store
            .register(aligned(&(0..16).map(|i| i as f64).collect::<Vec<_>>()))
            .unwrap_err();
        assert_eq!(
            err,
            StoreError::Full {
                requested: 128,
                capacity: 64
            }
        );
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn handle_reuse_after_release_is_collision_free() {
        let store = OperandStore::new(1 << 20);
        let a = store.register(aligned(&[7.0, 8.0])).unwrap();
        assert!(store.release(a.handle));
        // Different contents get a different handle (content addressing,
        // not slot reuse)...
        let b = store.register(aligned(&[9.0, 10.0])).unwrap();
        assert_ne!(a.handle, b.handle);
        // ...and the original contents get their original handle back.
        let again = store.register(aligned(&[7.0, 8.0])).unwrap();
        assert!(again.fresh, "released contents re-register as fresh");
        assert_eq!(again.handle, a.handle);
    }

    #[test]
    fn result_cache_partitions_lookups_and_evicts_lru() {
        let cache = ResultCache::new(2);
        let r = |bits: u64| CachedResult {
            bits,
            n: 4,
            path: ExecPath::Fused,
        };
        assert!(cache.get((1, 2)).is_none());
        cache.insert((1, 2), r(100));
        cache.insert((3, 4), r(200));
        assert_eq!(cache.get((1, 2)).unwrap().bits, 100);
        // (3,4) is now LRU; a third insert evicts it, not (1,2).
        cache.insert((5, 6), r(300));
        assert!(cache.get((3, 4)).is_none(), "LRU entry evicted");
        assert_eq!(cache.get((1, 2)).unwrap().bits, 100);
        assert_eq!(cache.get((5, 6)).unwrap().bits, 300);
        let stats = cache.stats();
        assert_eq!(stats.hits + stats.misses, stats.lookups);
        assert_eq!(stats.hits, 4);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.insertions, 3);
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.entries, 2);
    }

    #[test]
    fn result_cache_upsert_refreshes_without_recounting_insertions() {
        let cache = ResultCache::new(8);
        let r = CachedResult {
            bits: 42,
            n: 1,
            path: ExecPath::Sharded,
        };
        cache.insert((1, 1), r);
        cache.insert((1, 1), r);
        assert_eq!(cache.stats().insertions, 1);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn verify_counts_clean_entries_without_disturbing_them() {
        let store = OperandStore::new(1 << 20);
        let out = store.register(aligned(&[1.0, 2.0, 3.0])).unwrap();
        assert_eq!(store.verify(out.handle), ScrubOutcome::Clean);
        assert_eq!(store.verify(0xDEAD_BEEF), ScrubOutcome::Absent);
        assert!(store.contains(out.handle), "clean entry stays resident");
        let stats = store.stats();
        assert_eq!(stats.scrub_verified, 1);
        assert_eq!(stats.scrub_quarantined, 0);
        // Scrubs don't count as lookups and don't bump LRU.
        assert_eq!(stats.lookups, 0);
    }

    #[test]
    fn corrupted_entry_is_quarantined_and_never_served_again() {
        let store = OperandStore::new(1 << 20);
        let out = store.register(aligned(&[4.0, 5.0, 6.0])).unwrap();
        assert!(store.corrupt_resident(out.handle));
        assert_eq!(store.verify(out.handle), ScrubOutcome::Quarantined);
        assert!(!store.contains(out.handle), "quarantine removes the entry");
        assert!(store.lookup(out.handle).is_none());
        let stats = store.stats();
        assert_eq!(stats.scrub_quarantined, 1);
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.resident_bytes, 0);
        // Re-registering the clean contents recovers the handle.
        let again = store.register(aligned(&[4.0, 5.0, 6.0])).unwrap();
        assert!(again.fresh);
        assert_eq!(again.handle, out.handle);
        assert_eq!(store.verify(out.handle), ScrubOutcome::Clean);
    }

    #[test]
    fn quarantined_operand_stays_alive_through_an_in_flight_reader() {
        // The quarantine analogue of the RELEASE-under-reader pin: a
        // request that resolved the handle before the corruption keeps
        // its own clean snapshot through the Arc, and quarantine (a map
        // removal) cannot free it or swap corrupted bytes under it.
        let store = OperandStore::new(1 << 20);
        let values: Vec<f64> = (0..1000).map(|i| i as f64 * 0.25).collect();
        let out = store.register(aligned(&values)).unwrap();
        let held = store.lookup(out.handle).expect("resident");
        assert!(store.corrupt_resident(out.handle));
        assert_eq!(store.verify(out.handle), ScrubOutcome::Quarantined);
        for (i, v) in held.iter().enumerate() {
            assert_eq!(
                v.to_bits(),
                (i as f64 * 0.25).to_bits(),
                "reader snapshot stays bit-clean at index {i}"
            );
        }
    }

    #[test]
    fn scrub_all_quarantines_exactly_the_corrupted_entries() {
        let store = OperandStore::new(1 << 20);
        let a = store.register(aligned(&[1.0, 2.0])).unwrap();
        let b = store.register(aligned(&[3.0, 4.0])).unwrap();
        let c = store.register(aligned(&[5.0, 6.0])).unwrap();
        assert!(store.corrupt_resident(b.handle));
        let (clean, quarantined) = store.scrub_all();
        assert_eq!((clean, quarantined), (2, 1));
        assert!(store.contains(a.handle));
        assert!(!store.contains(b.handle));
        assert!(store.contains(c.handle));
        let stats = store.stats();
        assert_eq!(stats.scrub_passes, 1);
        assert_eq!(stats.scrub_verified, 2);
        assert_eq!(stats.scrub_quarantined, 1);
        assert_eq!(stats.resident_bytes, 32);
    }

    #[test]
    fn lookup_verified_gates_on_the_toggle() {
        let store = OperandStore::new(1 << 20);
        let out = store.register(aligned(&[7.0, 8.0])).unwrap();
        assert!(store.corrupt_resident(out.handle));
        // Verification off: the corrupted bytes are served (the PR-9
        // behavior, bit-for-bit — no hashing on the lookup path).
        assert!(!store.verify_on_lookup());
        let served = store.lookup_verified(out.handle).unwrap().unwrap();
        assert_eq!(served[0].to_bits(), 7.0f64.to_bits() ^ 1);
        // Verification on: the scrub detects, quarantines, and refuses.
        store.set_verify_on_lookup(true);
        assert_eq!(store.lookup_verified(out.handle), Err(out.handle));
        // The quarantined handle is now an ordinary unknown-handle miss.
        assert_eq!(store.lookup_verified(out.handle), Ok(None));
    }

    #[test]
    fn cache_poison_is_detected_by_bit_compare_and_evicted() {
        let cache = ResultCache::new(8);
        let r = CachedResult {
            bits: 0x4026_0000_0000_0000,
            n: 2,
            path: ExecPath::Fused,
        };
        cache.insert((1, 2), r);
        assert!(cache.poison((1, 2)));
        assert!(!cache.poison((9, 9)), "absent key cannot be poisoned");
        let hit = cache.get((1, 2)).expect("still memoized");
        assert_eq!(hit.bits, r.bits ^ 1, "poison flipped the low bit");
        // The verify-on-hit policy recomputes, sees the mismatch, evicts.
        assert!(cache.evict_poisoned((1, 2)));
        assert!(!cache.evict_poisoned((1, 2)), "second evict finds nothing");
        assert!(cache.get((1, 2)).is_none());
        cache.note_verified();
        let stats = cache.stats();
        assert_eq!(stats.poisoned, 1);
        assert_eq!(stats.verified, 1);
        assert_eq!(stats.evictions, 0, "poison eviction is not LRU pressure");
        assert_eq!(stats.hits + stats.misses, stats.lookups);
    }
}
