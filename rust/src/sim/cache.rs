//! Data-transfer engine: working-set size -> per-CL transfer cycles.
//!
//! Models the effects the paper *measures* but the ECM model idealizes:
//!
//! * smooth level transitions (set conflicts + streaming LRU eat into the
//!   nominal capacity; the crossover spreads over ~[0.7 C, 2 C]);
//! * hardware-prefetcher shortfall on L2-resident streams (Intel, Sect. 5.1);
//! * exposed memory latency on KNC when a kernel lacks the right software
//!   prefetch (Sect. 5.2: per-level kernels), divided by SMT (more
//!   outstanding misses);
//! * the POWER8 victim hierarchy: reduced effective L3, eviction traffic on
//!   the memory path, and SMT-dependent latency exposure (Sect. 5.3);
//! * per-pass loop overhead for small per-thread working sets (the PWR8
//!   "SMT breakdown in L1" of Fig. 7a).
//!
//! NOTE: this engine never calls into [`crate::ecm`]; the composition
//! hypothesis (what overlaps with what) is the physics shared with the
//! model, but every input here is computed independently and includes the
//! measured frictions the model deliberately ignores.

use crate::arch::{Machine, OverlapPolicy};
use crate::isa::{KernelLoop, OpClass};

/// How data reaches L1 for a given working set, as weights over source
/// levels (index 0 = L1, ..., caches.len() = memory). Weights sum to 1.
pub fn residence(m: &Machine, ws_bytes: u64) -> Vec<f64> {
    let nlev = m.caches.len() + 1;
    let mut weights = vec![0.0; nlev];
    // Effective capacities: set conflicts + streaming leave ~85% usable;
    // the machine may further derate its LLC (PWR8's 2 MB effective L3).
    let eff: Vec<f64> = m
        .caches
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let mut cap = 0.85 * c.capacity as f64;
            if i == m.caches.len() - 1 {
                if let Some(e) = m.calib.effective_llc_capacity {
                    cap = cap.min(e as f64);
                }
            }
            cap
        })
        .collect();

    let ws = ws_bytes as f64;
    // fraction of accesses served *beyond* a level of effective capacity
    // `cap` (log-space ramp around the capacity).
    let beyond = |cap: f64| -> f64 {
        let lo = 0.7 * cap;
        let hi = 2.0 * cap;
        if ws <= lo {
            0.0
        } else if ws >= hi {
            1.0
        } else {
            (ws.ln() - lo.ln()) / (hi.ln() - lo.ln())
        }
    };

    let mut remaining = 1.0;
    for (i, cap) in eff.iter().enumerate() {
        let b = beyond(*cap);
        weights[i] = remaining * (1.0 - b);
        remaining *= b;
    }
    weights[nlev - 1] = remaining;
    weights
}

/// Per-CL-of-work data-transfer cycles for one core.
#[derive(Clone, Debug)]
pub struct DataCycles {
    /// Data-transfer cycles per CL of work (weighted over source levels).
    pub cycles: f64,
    /// Fraction of traffic served from memory (for contention modeling).
    pub mem_fraction: f64,
}

/// Options describing how the benchmark runs (measurement protocol).
#[derive(Clone, Copy, Debug)]
pub struct MeasureOpts {
    /// SMT threads per core.
    pub smt: u32,
    /// Untuned/compiler binary: no platform software prefetch (KNC exposed
    /// ring latency; Sect. 5.2's "compiler generated" series).
    pub untuned: bool,
    /// Deterministic noise seed.
    pub seed: u64,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        Self {
            smt: 1,
            untuned: false,
            seed: 1,
        }
    }
}

/// Effective memory latency penalty for this kernel/protocol on `m`.
fn mem_penalty(m: &Machine, k: &KernelLoop, opts: &MeasureOpts) -> f64 {
    if m.shorthand == "KNC" {
        let has_pf2 = k.count(|o| matches!(o, OpClass::Prefetch(2))) > 0;
        if has_pf2 {
            17.0
        } else if opts.untuned || !k.simd {
            // No software prefetch at all: the ring latency is exposed and
            // only SMT's outstanding misses hide part of it.
            80.0 / opts.smt.max(1) as f64
        } else {
            m.mem.latency_penalty
        }
    } else {
        m.mem.latency_penalty
    }
}

/// POWER8 latency exposure per level (Fig. 7a): load-miss latency is hidden
/// only by SMT concurrency.
fn pwr8_exposure(m: &Machine, level: usize, smt: u32) -> f64 {
    if m.shorthand != "PWR8" {
        return 0.0;
    }
    let smt = smt.max(1) as f64;
    match level {
        0 | 1 => 0.0,
        // L3: strong latency effect, compensated only by SMT-8 (Fig. 7a).
        2 => 24.0 / smt,
        // Memory: moderate exposure; SMT-4 suffices.
        _ => 12.0 / smt,
    }
}

/// POWER8 eviction-overlap factor on the memory path: more threads give the
/// memory subsystem more concurrency to overlap L2->L3 evictions with
/// reloads (Sect. 5.3: only SMT-4 beats the no-overlap bound of 22 cy).
fn pwr8_evict_factor(smt: u32) -> f64 {
    match smt {
        0..=2 => 1.0,
        4 => 0.5,
        _ => 0.75, // SMT-8: contention gives some of the overlap back
    }
}

/// Compute the per-CL data-transfer cycles for `kernel` on `m` with the
/// given working set, including frictions. Single core.
pub fn data_cycles(m: &Machine, k: &KernelLoop, ws_bytes: u64, opts: &MeasureOpts) -> DataCycles {
    let w = residence(m, ws_bytes);
    let streams = k.streams as f64;
    let nlev = w.len();
    let mut total = 0.0;

    for (lvl, weight) in w.iter().enumerate().skip(1) {
        if *weight <= 0.0 {
            continue;
        }
        let mut cost = 0.0;
        if m.victim_llc && lvl == nlev - 1 {
            // Victim path: Mem -> L2 directly, plus L2 -> L3 evictions.
            cost += streams * m.cache_cycles_per_cl(1); // L2 -> L1
            cost += streams * m.cache_cycles_per_cl(m.caches.len() - 1)
                * pwr8_evict_factor(opts.smt); // evictions
            cost += streams * m.mem_cycles_per_cl();
        } else {
            // Cross every hop from the source level inward.
            for h in 1..=lvl {
                if h < nlev - 1 {
                    cost += streams * m.cache_cycles_per_cl(h);
                    cost += m.caches[h].latency_penalty;
                } else {
                    cost += streams * m.mem_cycles_per_cl();
                    cost += mem_penalty(m, k, opts);
                }
            }
        }
        // Hardware-prefetcher shortfall on cache-resident streams (Intel's
        // L2/L3 friction, Sect. 5.1).
        if lvl >= 1 && lvl < nlev - 1 {
            cost += m.calib.l2_friction_cy_per_cl * streams;
        }
        if lvl == nlev - 1 {
            cost += m.calib.mem_friction_cy_per_cl * streams;
        }
        cost += pwr8_exposure(m, lvl, opts.smt);
        total += weight * cost;
    }

    DataCycles {
        cycles: total,
        mem_fraction: w[nlev - 1],
    }
}

/// Compose core and data cycles per the machine's overlap behavior,
/// yielding "measured" cycles per CL of work (single core). The caller is
/// responsible for any core-efficiency derating (it is kernel-dependent:
/// the paper observed the PWR8 20-30% shortfall on the SIMD kernels).
pub fn compose(m: &Machine, core_cy_per_cl: f64, nol_cy_per_cl: f64, data: &DataCycles) -> f64 {
    match m.overlap {
        OverlapPolicy::FullOverlap => core_cy_per_cl.max(data.cycles),
        _ => core_cy_per_cl.max(nol_cy_per_cl + data.cycles),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::ecm::derive::{kernel_for, MemLevel};
    use crate::isa::Variant;
    use crate::util::units::{Precision, KIB, MIB};

    fn hsw_kernel() -> KernelLoop {
        kernel_for(&haswell(), Variant::NaiveSimd, Precision::Sp, MemLevel::Mem)
    }

    #[test]
    fn residence_sums_to_one_and_moves_outward() {
        let m = haswell();
        let mut last_mem = 0.0;
        for ws in [8 * KIB, 64 * KIB, MIB, 8 * MIB, 256 * MIB] {
            let w = residence(&m, ws);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
            let mem = *w.last().unwrap();
            assert!(mem >= last_mem - 1e-9, "mem fraction must grow: {w:?}");
            last_mem = mem;
        }
    }

    #[test]
    fn small_ws_is_l1_resident() {
        let w = residence(&haswell(), 8 * KIB);
        assert!(w[0] > 0.99, "{w:?}");
    }

    #[test]
    fn huge_ws_is_memory_resident() {
        let w = residence(&haswell(), 2 * 1024 * MIB);
        assert!(w.last().unwrap() > &0.99, "{w:?}");
    }

    #[test]
    fn pwr8_effective_l3_is_2mb() {
        // At 4 MiB (within nominal 8 MB L3 but beyond the effective 2 MB)
        // a sizeable fraction must already come from memory.
        let w = residence(&power8(), 4 * MIB);
        assert!(w.last().unwrap() > &0.3, "{w:?}");
    }

    #[test]
    fn data_cycles_grow_with_ws() {
        let m = haswell();
        let k = hsw_kernel();
        let opts = MeasureOpts::default();
        let mut last = 0.0;
        for ws in [8 * KIB, 128 * KIB, 4 * MIB, 512 * MIB] {
            let d = data_cycles(&m, &k, ws, &opts);
            assert!(d.cycles >= last - 1e-9, "ws {ws}: {} < {last}", d.cycles);
            last = d.cycles;
        }
    }

    #[test]
    fn hsw_mem_data_cost_near_model() {
        // Deep in memory the data term must approach the ECM's
        // 2 + 4+1 + 9.2+1 (+ friction) ~ 17.2..18.5 cy/CL.
        let m = haswell();
        let k = hsw_kernel();
        let d = data_cycles(&m, &k, 512 * MIB, &MeasureOpts::default());
        assert!(
            (17.0..19.5).contains(&d.cycles),
            "mem data cycles = {}",
            d.cycles
        );
    }

    #[test]
    fn knc_untuned_pays_exposed_latency() {
        let m = knights_corner();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let tuned_opts = MeasureOpts { smt: 1, untuned: false, seed: 1 };
        let untuned_opts = MeasureOpts { smt: 1, untuned: true, seed: 1 };
        let tuned = data_cycles(&m, &k, 512 * MIB, &tuned_opts);
        let untuned = data_cycles(&m, &k, 512 * MIB, &untuned_opts);
        assert!(
            untuned.cycles > tuned.cycles + 30.0,
            "untuned {} vs tuned {}",
            untuned.cycles,
            tuned.cycles
        );
        // SMT hides part of the exposure.
        let smt4 = data_cycles(&m, &k, 512 * MIB, &MeasureOpts { smt: 4, untuned: true, seed: 1 });
        assert!(smt4.cycles < untuned.cycles);
    }

    #[test]
    fn knc_mem_kernel_gets_prefetch_credit() {
        let m = knights_corner();
        let plain = kernel_for(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::L1);
        let memk = kernel_for(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);
        let opts = MeasureOpts { smt: 2, untuned: false, seed: 1 };
        let d_plain = data_cycles(&m, &plain, 512 * MIB, &opts);
        let d_mem = data_cycles(&m, &memk, 512 * MIB, &opts);
        assert!(d_mem.cycles < d_plain.cycles, "{} vs {}", d_mem.cycles, d_plain.cycles);
    }

    #[test]
    fn pwr8_smt_helps_l3() {
        let m = power8();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let ws = MIB; // L3-resident (within effective 2 MB)
        let d1 = data_cycles(&m, &k, ws, &MeasureOpts { smt: 1, untuned: false, seed: 1 });
        let d8 = data_cycles(&m, &k, ws, &MeasureOpts { smt: 8, untuned: false, seed: 1 });
        assert!(d8.cycles < d1.cycles, "SMT-8 {} vs SMT-1 {}", d8.cycles, d1.cycles);
    }

    #[test]
    fn pwr8_smt4_beats_no_overlap_bound_in_memory() {
        // Sect. 5.3: only SMT-4 runs faster than the 22-cy no-overlap bound.
        let m = power8();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let d4 = data_cycles(&m, &k, 512 * MIB, &MeasureOpts { smt: 4, untuned: false, seed: 1 });
        let d2 = data_cycles(&m, &k, 512 * MIB, &MeasureOpts { smt: 2, untuned: false, seed: 1 });
        assert!(d4.cycles < 22.0, "SMT-4 {}", d4.cycles);
        assert!(d2.cycles >= 21.0, "SMT-2 {}", d2.cycles);
    }

    #[test]
    fn compose_overlap_rules() {
        let hsw = haswell();
        let d = DataCycles { cycles: 10.0, mem_fraction: 1.0 };
        // Intel: max(T_OL, T_nOL + data)
        assert_eq!(compose(&hsw, 8.0, 2.0, &d), 12.0);
        assert_eq!(compose(&hsw, 15.0, 2.0, &d), 15.0);
        let p8 = power8();
        // PWR8: max(core, data) — no T_nOL term.
        assert_eq!(compose(&p8, 9.0, 0.0, &d), 10.0);
        assert_eq!(compose(&p8, 12.0, 0.0, &d), 12.0);
    }
}
