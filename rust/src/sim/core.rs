//! Scoreboard core simulator: cycle-by-cycle issue of the kernel loop onto
//! the machine's execution ports, respecting dependencies, latencies, issue
//! width and ordering discipline (OoO vs in-order paired issue), with
//! optional SMT threads sharing the ports.
//!
//! This is the "measurement" side of the in-core story: given the same
//! hand-scheduled kernels, it reproduces effects the throughput-only view
//! misses — exactly the effects Sect. 4.2.1/Fig. 3 of the paper derives by
//! hand (FMA latency stretching the Kahan recurrence to 16 cy per body, the
//! 5-way FMA-trick variant reaching 6.4 cy/CL, etc.).

use crate::arch::Machine;
use crate::isa::{KernelLoop, OpClass};

/// Result of a steady-state core simulation.
#[derive(Clone, Debug)]
pub struct CoreResult {
    /// Steady-state cycles per loop body, per thread.
    pub cycles_per_body: f64,
    /// Steady-state cycles per scalar update, aggregated over SMT threads.
    pub cycles_per_update: f64,
    /// Cycles per cache line of work (updates_per_cl updates), aggregated.
    pub cycles_per_cl: f64,
    /// Utilization of each port in steady state (0..1).
    pub port_util: Vec<f64>,
    /// Number of simulated iterations used for the measurement window.
    pub window_iters: usize,
}

/// One dynamic (per-iteration) instruction instance.
#[derive(Clone, Copy, Debug)]
struct DynOp {
    /// Index into the kernel body.
    body_ix: u32,
    /// Iteration number.
    iter: u32,
    /// Issue cycle (u64::MAX = not yet issued).
    issue: u64,
    /// Cached earliest-ready cycle (UNKNOWN until all producers issued).
    ready: u64,
}

const UNISSUED: u64 = u64::MAX;
const UNKNOWN: u64 = u64::MAX - 1;

/// Per-thread stream state.
struct Stream<'k> {
    kernel: &'k KernelLoop,
    ops: Vec<DynOp>,
    /// Next un-issued op index (all before it are issued).
    head: usize,
    /// For each body instruction: source dependency positions, encoded as
    /// (body_ix of producer, carried?) — carried means "previous iteration".
    deps: Vec<Vec<(u32, bool)>>,
    /// Issue cycle of each (iter, body_ix) producer we still need: we keep
    /// the full issue history (iters are bounded in this sim).
    issue_log: Vec<u64>,
    /// Latency of each body instruction.
    lat: Vec<u64>,
    /// Port candidates per body instruction (empty = no port needed).
    port_cands: Vec<Vec<usize>>,
    /// Consumes an issue slot? (Movs are renamed away on OoO cores.)
    takes_slot: Vec<bool>,
}

impl<'k> Stream<'k> {
    fn new(kernel: &'k KernelLoop, m: &Machine, iters: u32) -> Self {
        let body = &kernel.body;
        // Dependency extraction: for each instruction's source register,
        // find the producer within this iteration (earlier write) or mark
        // carried (write in previous iteration).
        let mut deps: Vec<Vec<(u32, bool)>> = Vec::with_capacity(body.len());
        for (ix, ins) in body.iter().enumerate() {
            let mut d = Vec::new();
            for &src in &ins.srcs {
                // Last write strictly before ix.
                let prior = body[..ix].iter().rposition(|p| p.dst == Some(src));
                match prior {
                    Some(p) => d.push((p as u32, false)),
                    None => {
                        // Carried if written later in the body; otherwise a
                        // loop-invariant constant (no dependency).
                        if let Some(p) = body.iter().rposition(|p| p.dst == Some(src)) {
                            d.push((p as u32, true));
                        }
                    }
                }
            }
            deps.push(d);
        }

        let lat: Vec<u64> = body.iter().map(|i| m.lat.of(&i.op) as u64).collect();
        let port_cands: Vec<Vec<usize>> = body
            .iter()
            .map(|i| match i.op {
                // Renamed away on OoO; an issue slot (either pipe) in-order.
                OpClass::Mov => {
                    if m.in_order {
                        m.ports_for(&OpClass::Mov)
                    } else {
                        vec![]
                    }
                }
                ref op => m.ports_for(op),
            })
            .collect();
        let takes_slot: Vec<bool> = body
            .iter()
            .map(|i| !(matches!(i.op, OpClass::Mov) && !m.in_order))
            .collect();

        let total = body.len() * iters as usize;
        let mut ops = Vec::with_capacity(total);
        for iter in 0..iters {
            for body_ix in 0..body.len() {
                ops.push(DynOp {
                    body_ix: body_ix as u32,
                    iter,
                    issue: UNISSUED,
                    ready: UNKNOWN,
                });
            }
        }
        Self {
            kernel,
            issue_log: vec![UNISSUED; total],
            ops,
            head: 0,
            deps,
            lat,
            port_cands,
            takes_slot,
        }
    }


    fn op_index(&self, iter: u32, body_ix: u32) -> usize {
        iter as usize * self.kernel.body.len() + body_ix as usize
    }

    /// Earliest cycle at which op `i` has all operands available; cached in
    /// the op once all producers have issued (the scan hot path touches
    /// every waiting op every cycle, so avoiding the dependency walk pays).
    fn ready_cycle(&mut self, i: usize) -> u64 {
        let cached = self.ops[i].ready;
        if cached != UNKNOWN {
            return cached;
        }
        let op = self.ops[i];
        let mut ready = 0u64;
        for &(producer, carried) in &self.deps[op.body_ix as usize] {
            let (p_iter, valid) = if carried {
                match op.iter.checked_sub(1) {
                    Some(pi) => (pi, true),
                    None => (0, false), // first iteration: initialized regs
                }
            } else {
                (op.iter, true)
            };
            if !valid {
                continue;
            }
            let p = self.op_index(p_iter, producer);
            let p_issue = self.issue_log[p];
            if p_issue == UNISSUED {
                return UNKNOWN; // producer not scheduled yet
            }
            ready = ready.max(p_issue + self.lat[producer as usize]);
        }
        self.ops[i].ready = ready;
        ready
    }

    fn done(&self) -> bool {
        self.head >= self.ops.len()
    }
}

/// Memoized [`simulate_core`]: sweeps and figure generators hit the same
/// (machine, kernel, smt) configurations hundreds of times; the steady
/// state is deterministic, so cache it process-wide.
pub fn simulate_core_cached(m: &Machine, kernel: &KernelLoop, smt: u32) -> CoreResult {
    use std::collections::HashMap;
    use std::sync::Mutex;
    static MEMO: once_cell::sync::Lazy<Mutex<HashMap<String, CoreResult>>> =
        once_cell::sync::Lazy::new(|| Mutex::new(HashMap::new()));
    // Key includes a machine fingerprint: custom machines may share a
    // shorthand, so fold in the parameters that affect scheduling.
    let key = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{}",
        m.shorthand,
        m.freq_ghz,
        m.ports.len(),
        m.issue_width,
        m.in_order,
        m.lat.add,
        m.lat.fma,
        kernel.name,
        smt
    );
    if let Some(r) = MEMO.lock().unwrap().get(&key) {
        return r.clone();
    }
    let r = simulate_core(m, kernel, smt);
    MEMO.lock().unwrap().insert(key, r.clone());
    r
}

/// Simulate `kernel` on `m` with `smt` threads until steady state.
/// Returns per-body and per-update steady-state cycle counts.
pub fn simulate_core(m: &Machine, kernel: &KernelLoop, smt: u32) -> CoreResult {
    let smt = smt.max(1);
    // Enough iterations to wash out warmup (longest transients observed:
    // ~25 iterations for the PWR8 SMT-8 chains; 50 is a 2x margin).
    let iters: u32 = 150;
    let warm: u32 = 50;

    let mut streams: Vec<Stream> = (0..smt).map(|_| Stream::new(kernel, m, iters)).collect();

    // Static port pressure (expected ops per body per port, splitting each
    // op evenly over its candidates): used to steer ops away from ports
    // that other classes need (e.g. HSW ADDs own P1, so FMAs prefer P0;
    // KNC loads prefer the V-pipe and leave the U-pipe to arithmetic).
    let mut pressure = vec![0.0f64; m.ports.len()];
    for cands in &streams[0].port_cands {
        if !cands.is_empty() {
            for &p in cands {
                pressure[p] += 1.0 / cands.len() as f64;
            }
        }
    }

    // Port busy bitmap per cycle: ports are fully pipelined, 1 op/cy each.
    // Indexed [cycle % HORIZON][port]; cleared as the cycle pointer moves.
    let nports = m.ports.len();
    let mut cycle: u64 = 0;
    let mut port_busy_counts = vec![0u64; nports];

    // The scheduling loop. For each cycle: each thread (rotating priority)
    // scans its window in program order and issues ready ops onto free
    // ports, bounded by the machine's issue width (shared across threads,
    // as SMT shares the front end).
    let window_ooo = 192usize;
    let mut port_free = vec![true; nports];
    let max_cycles = 4_000_000u64;

    while streams.iter().any(|s| !s.done()) && cycle < max_cycles {
        for p in port_free.iter_mut() {
            *p = true;
        }
        let mut slots = m.issue_width;
        let t0 = (cycle % smt as u64) as usize;
        for toff in 0..smt as usize {
            let s = &mut streams[(t0 + toff) % smt as usize];
            if s.done() || slots == 0 {
                continue;
            }
            let window = if m.in_order {
                // Strictly in-order: scan from head, stop at first stall.
                s.ops.len().min(s.head + m.issue_width as usize)
            } else {
                s.ops.len().min(s.head + window_ooo)
            };

            // Candidate pick order: strict program order (= oldest-ready
            // first, which is what both in-order issue and real OoO pick
            // logic do). NOTE: height/criticality priority was tried and
            // *hurts* resource-bound recurrences — in a steady-state loop
            // every op on the carried cycle is equally critical, and
            // preferring chain heads starves chain tails (see EXPERIMENTS.md
            // §Sim-fidelity).
            for i in s.head..window {
                if slots == 0 {
                    break;
                }
                if s.ops[i].issue != UNISSUED {
                    continue;
                }
                let ready = s.ready_cycle(i);
                let can_issue = ready != UNKNOWN && ready <= cycle;
                if can_issue {
                    // Free candidate port with the least static pressure.
                    let cands = &s.port_cands[s.ops[i].body_ix as usize];
                    let chosen = if cands.is_empty() {
                        Some(None) // no port needed (renamed mov)
                    } else {
                        cands
                            .iter()
                            .copied()
                            .filter(|&p| port_free[p])
                            .min_by(|&a, &b| pressure[a].partial_cmp(&pressure[b]).unwrap())
                            .map(Some)
                    };
                    if let Some(port) = chosen {
                        if let Some(p) = port {
                            port_free[p] = false;
                            port_busy_counts[p] += 1;
                        }
                        s.ops[i].issue = cycle;
                        s.issue_log[i] = cycle;
                        if s.takes_slot[s.ops[i].body_ix as usize] {
                            slots -= 1;
                        }
                        if i == s.head {
                            while s.head < s.ops.len() && s.ops[s.head].issue != UNISSUED {
                                s.head += 1;
                            }
                        }
                        continue;
                    }
                }
                // In-order: cannot skip a stalled op.
                if m.in_order {
                    break;
                }
            }
        }
        cycle += 1;
    }

    assert!(
        cycle < max_cycles,
        "core sim did not converge for kernel {}",
        kernel.name
    );

    // Steady-state II per thread: regression over first-op issue cycles of
    // the measurement window.
    let mut total_ii = 0.0;
    for s in &streams {
        let t_warm = s.issue_log[s.op_index(warm, 0)];
        let t_end = s.issue_log[s.op_index(iters - 1, 0)];
        total_ii += (t_end - t_warm) as f64 / (iters - 1 - warm) as f64;
    }
    // Per-thread steady-state initiation interval; all smt threads complete
    // one body each per interval, so aggregate cost per update divides by
    // (updates_per_body * smt).
    let per_thread_ii = total_ii / smt as f64;
    let cycles_per_body = per_thread_ii;
    let cycles_per_update = per_thread_ii / (kernel.updates_per_body as f64 * smt as f64);
    let upcl = kernel.updates_per_cl(m.cacheline) as f64;
    let denom_cycles = cycle as f64;
    let port_util: Vec<f64> = port_busy_counts
        .iter()
        .map(|&c| c as f64 / denom_cycles)
        .collect();

    CoreResult {
        cycles_per_body,
        cycles_per_update,
        cycles_per_cl: cycles_per_update * upcl,
        port_util,
        window_iters: (iters - warm) as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::isa::variants::{build, build_sched, Sched, Variant};
    use crate::util::units::Precision;

    fn hsw_kernel(v: Variant, unroll: u32) -> KernelLoop {
        build(v, 8, unroll, Precision::Sp, &[])
    }

    #[test]
    fn naive_hsw_hits_load_or_fma_limit() {
        // Sufficiently unrolled naive sdot: 2 FMA per CL on 2 ports -> the
        // in-core limit is 1 cy/CL for arithmetic; with loads on 2 ports the
        // overall core limit is T_nOL = 2 cy/CL (Sect. 4.1.1). The full-body
        // scoreboard should land at ~2 cy/CL (loads bound).
        let m = haswell();
        let k = hsw_kernel(Variant::NaiveSimd, 10);
        let r = simulate_core(&m, &k, 1);
        assert!(
            (r.cycles_per_cl - 2.0).abs() < 0.25,
            "naive HSW cy/CL = {}",
            r.cycles_per_cl
        );
    }

    #[test]
    fn kahan_avx_hsw_is_add_bound_at_8() {
        // Sect. 4.2.1: AVX Kahan without FMA -> T_OL = 8 cy/CL (ADD port).
        let m = haswell();
        let k = hsw_kernel(Variant::KahanSimd, 4);
        let r = simulate_core(&m, &k, 1);
        assert!(
            (r.cycles_per_cl - 8.0).abs() < 0.8,
            "kahan-avx HSW cy/CL = {}",
            r.cycles_per_cl
        );
    }

    #[test]
    fn kahan_fma_hsw_latency_bound_near_8() {
        // Fig. 3 left: the paper's hand schedule of the 4-way unrolled FMA
        // Kahan runs at 16 cy / 2 CL (8 cy/CL); the pure recurrence bound is
        // 5+3+3+3 = 14 cy (7 cy/CL), which an ideal OoO scheduler attains.
        // Our scoreboard finds the 14-cy schedule; we accept [7, 8] and pin
        // the paper's published 8 via the documented override in ecm::derive.
        let m = haswell();
        let k = hsw_kernel(Variant::KahanSimdFma, 4);
        let r = simulate_core(&m, &k, 1);
        assert!(
            (7.0..=8.5).contains(&r.cycles_per_cl),
            "kahan-fma HSW cy/CL = {} (paper: 8, RecMII bound: 7)",
            r.cycles_per_cl
        );
    }

    #[test]
    fn kahan_fma5_hsw_near_6_4() {
        // Fig. 3 right: the 5-way FMA-as-ADD trick. Ideal modulo schedule:
        // 16 cy / 2.5 CL = 6.4 (the ECM T_OL). The greedy oldest-first
        // scheduler (= the hardware's pick logic from a cold start) lands at
        // 18 cy -> 7.2 cy/CL, which matches the paper's *measured* L1 value
        // (Fig. 10a: HSW ~0.45 cy/update = 7.2 cy/CL vs 0.4 predicted).
        let m = haswell();
        let k = hsw_kernel(Variant::KahanSimdFma5, 5);
        let r = simulate_core(&m, &k, 1);
        assert!(
            (6.4..=7.5).contains(&r.cycles_per_cl),
            "kahan-fma5 HSW cy/CL = {} (model 6.4, paper measured ~7.2)",
            r.cycles_per_cl
        );
    }

    #[test]
    fn kahan_scalar_is_latency_dominated() {
        // Compiler variant: one 4-op recurrence (MUL off the chain) at
        // 3 cy ADD latency -> ~12 cy per scalar update on HSW.
        let m = haswell();
        let k = build(Variant::KahanScalar, 1, 1, Precision::Sp, &[]);
        let r = simulate_core(&m, &k, 1);
        assert!(
            (r.cycles_per_update - 12.0).abs() < 1.5,
            "scalar kahan cy/update = {} (expect ~12)",
            r.cycles_per_update
        );
    }

    #[test]
    fn pwr8_kahan_is_vsx_bound_at_16() {
        // Sect. 4.2.3: 32 FMA/ADD on 2 VSX units -> 16 cy per 128-B CL.
        let m = power8();
        let k = build(Variant::KahanSimdFma, 4, 16, Precision::Sp, &[]);
        let r = simulate_core(&m, &k, 2);
        assert!(
            (r.cycles_per_cl - 16.0).abs() < 2.0,
            "pwr8 kahan cy/CL = {} (paper: 16)",
            r.cycles_per_cl
        );
    }

    #[test]
    fn pwr8_naive_is_load_bound_at_8() {
        let m = power8();
        let k = build(Variant::NaiveSimd, 4, 16, Precision::Sp, &[]);
        let r = simulate_core(&m, &k, 2);
        assert!(
            (r.cycles_per_cl - 8.0).abs() < 1.0,
            "pwr8 naive cy/CL = {} (paper: 8)",
            r.cycles_per_cl
        );
    }

    #[test]
    fn knc_kahan_u_pipe_bound_at_4() {
        // Sect. 4.2.2: 1 FMA + 3 ADD per 16-SP chunk, U-pipe only -> 4 cy/CL
        // (with 2-SMT hiding the 4-cy vector latency, as the paper runs it).
        let m = knights_corner();
        let k = build_sched(
            Variant::KahanSimdFma,
            16,
            4,
            Precision::Sp,
            &[],
            Sched::SoftwarePipelined,
        );
        let r = simulate_core(&m, &k, 2);
        assert!(
            (r.cycles_per_cl - 4.0).abs() < 0.6,
            "knc kahan cy/CL = {} (paper: 4)",
            r.cycles_per_cl
        );
    }

    #[test]
    fn knc_naive_pairs_loads_with_fma() {
        // Naive on KNC: 2 loads + 1 FMA per CL; loads pair onto U/V pipes ->
        // ~2 cy/CL core limit (T_nOL = 2 in the paper's input).
        let m = knights_corner();
        let k = build_sched(
            Variant::NaiveSimd,
            16,
            4,
            Precision::Sp,
            &[],
            Sched::SoftwarePipelined,
        );
        let r = simulate_core(&m, &k, 2);
        assert!(
            (r.cycles_per_cl - 2.0).abs() < 0.4,
            "knc naive cy/CL = {}",
            r.cycles_per_cl
        );
    }

    #[test]
    fn smt_hides_latency_on_pwr8() {
        // Single-thread PWR8 Kahan with low unroll is latency-bound; SMT-4
        // must recover throughput (Fig. 7a's story in core terms).
        let m = power8();
        let k = build(Variant::KahanSimdFma, 4, 4, Precision::Sp, &[]);
        let one = simulate_core(&m, &k, 1);
        let four = simulate_core(&m, &k, 4);
        assert!(
            four.cycles_per_update < one.cycles_per_update * 0.5,
            "SMT-4 {} vs SMT-1 {}",
            four.cycles_per_update,
            one.cycles_per_update
        );
    }

    #[test]
    fn port_utilization_sane() {
        let m = haswell();
        let k = hsw_kernel(Variant::KahanSimd, 4);
        let r = simulate_core(&m, &k, 1);
        for (i, u) in r.port_util.iter().enumerate() {
            assert!((0.0..=1.0).contains(u), "port {i} util {u}");
        }
        // ADD port (P1) should be the hot one.
        assert!(r.port_util[1] > 0.8, "P1 util {}", r.port_util[1]);
    }
}
