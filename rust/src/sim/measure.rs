//! The "likwid-bench" front door of the virtual testbed: single-core
//! working-set sweeps (Figs. 5–7) and in-memory core scans (Figs. 8–9),
//! with deterministic measurement noise.

use crate::arch::Machine;
use crate::isa::{KernelLoop, OpClass};
use crate::util::rng::hash_noise;
use crate::util::units::cycles_per_cl_to_gups;

pub use super::cache::MeasureOpts;
use super::cache::{compose, data_cycles};
use super::core::simulate_core_cached;

/// One simulated measurement.
#[derive(Clone, Debug)]
pub struct MeasuredPoint {
    /// Working-set size in bytes (both streams together).
    pub ws_bytes: u64,
    /// Measured cycles per cache line of work.
    pub cy_per_cl: f64,
    /// Measured performance, GUP/s (single core unless noted).
    pub gups: f64,
}

/// Loop startup/teardown overhead per benchmark pass, amortized over the
/// cache lines each thread processes (the Fig. 7a short-loop breakdown).
fn loop_overhead_cy_per_cl(m: &Machine, ws_bytes: u64, smt: u32) -> f64 {
    const OVERHEAD_CY: f64 = 30.0;
    let cls = ((ws_bytes / 2).max(1) / m.cacheline).max(1); // per-stream lines
    let per_thread = (cls / smt.max(1) as u64).max(1);
    OVERHEAD_CY / per_thread as f64
}

/// Deterministic measurement jitter for a sweep point, including the PWR8
/// erratic window (Sect. 5.3).
fn noise_factor(m: &Machine, ws_bytes: u64, seed: u64) -> f64 {
    let mut rel = m.calib.noise_rel;
    if let Some((lo, hi, amp)) = m.calib.erratic_window {
        if ws_bytes >= lo && ws_bytes <= hi {
            rel += amp;
        }
    }
    // Noise inflates runtime only (one-sided, like real interference).
    1.0 + rel * (0.5 + 0.5 * hash_noise(ws_bytes ^ seed.rotate_left(17), 0xECA1))
}

/// Single-core in-core cycle terms for the composition: total steady-state
/// core cycles and the non-overlapping (L1 transfer) share.
fn core_terms(m: &Machine, k: &KernelLoop, smt: u32) -> (f64, f64) {
    let core = simulate_core_cached(m, k, smt);
    // The measured instruction-throughput shortfall (PWR8 misses by 20-30%,
    // Sect. 5.5) was observed on the throughput-bound SIMD kernels; the
    // latency-bound scalar code is not derated.
    let eff = if k.simd { m.calib.core_efficiency } else { 1.0 };
    let loads = k.count(|o| o.is_l1_transfer()) as f64
        + k.count(|o| matches!(o, OpClass::Prefetch(_))) as f64;
    let load_ports = m.throughput(&OpClass::Load).max(1.0);
    let nol =
        loads / load_ports / k.cachelines_per_body(m.cacheline) / smt.max(1) as f64;
    (core.cycles_per_cl / eff, nol)
}

/// Working-set sweep: "measured" single-core cy/CL and GUP/s per size.
pub fn sweep(
    m: &Machine,
    k: &KernelLoop,
    sizes: &[u64],
    opts: &MeasureOpts,
) -> Vec<MeasuredPoint> {
    let (core_cy, nol_cy) = core_terms(m, k, opts.smt);
    let upcl = k.updates_per_cl(m.cacheline);
    sizes
        .iter()
        .map(|&ws| {
            let d = data_cycles(m, k, ws, opts);
            let mut cy = compose(m, core_cy, nol_cy, &d);
            cy += loop_overhead_cy_per_cl(m, ws, opts.smt);
            cy *= noise_factor(m, ws, opts.seed);
            MeasuredPoint {
                ws_bytes: ws,
                cy_per_cl: cy,
                gups: cycles_per_cl_to_gups(cy, m.freq_ghz, upcl),
            }
        })
        .collect()
}

/// Default log-spaced working-set sizes for the Fig. 5-7 sweeps (bytes,
/// both streams; from in-L1 to deep in memory).
pub fn default_sweep_sizes(max_bytes: u64) -> Vec<u64> {
    let mut v = Vec::new();
    let mut ws = 4 * 1024u64;
    while ws <= max_bytes {
        v.push(ws);
        // ~4 points per octave.
        ws = (ws as f64 * 1.19) as u64 + 64;
    }
    v
}

/// In-memory core scan ("measured"): chip-level GUP/s for n = 1..=cores.
/// Delegates contention to [`super::multicore`].
pub fn corescan(
    m: &Machine,
    k: &KernelLoop,
    ws_bytes: u64,
    opts: &MeasureOpts,
) -> Vec<(u32, f64)> {
    let pts = sweep(m, k, &[ws_bytes], opts);
    let single = &pts[0];
    super::multicore::scaling_curve(m, k, single.gups, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::ecm::derive::{kernel_for, MemLevel};
    use crate::isa::Variant;
    use crate::util::units::{Precision, GIB, KIB, MIB};

    #[test]
    fn hsw_naive_sweep_matches_paper_shape() {
        // Fig. 5a plain sdot: ~2 cy/CL in L1, ~4-5.5 in L2, ~9-11 in L3,
        // ~19-21.5 in memory.
        let m = haswell();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let opts = MeasureOpts::default();
        let p = |ws| sweep(&m, &k, &[ws], &opts)[0].cy_per_cl;
        let l1 = p(16 * KIB);
        let l2 = p(128 * KIB);
        let l3 = p(4 * MIB);
        let mem = p(GIB);
        assert!((1.9..2.6).contains(&l1), "L1 {l1}");
        assert!((3.8..6.0).contains(&l2), "L2 {l2}");
        assert!((8.5..12.0).contains(&l3), "L3 {l3}");
        assert!((18.5..22.0).contains(&mem), "Mem {mem}");
    }

    #[test]
    fn hsw_kahan_avx_flat_until_l3() {
        // Fig. 5a: AVX Kahan runs at 8 cy/CL in L1 *and* L2 (core-bound),
        // meets the naive line in L3/memory — "Kahan for free".
        let m = haswell();
        let k = kernel_for(&m, Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
        let opts = MeasureOpts::default();
        let pts = sweep(&m, &k, &[16 * KIB, 128 * KIB, GIB], &opts);
        assert!((7.9..8.8).contains(&pts[0].cy_per_cl), "L1 {}", pts[0].cy_per_cl);
        assert!((7.9..8.8).contains(&pts[1].cy_per_cl), "L2 {}", pts[1].cy_per_cl);
        assert!((18.5..22.0).contains(&pts[2].cy_per_cl), "Mem {}", pts[2].cy_per_cl);
        // naive and Kahan agree in memory within noise:
        let kn = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let nmem = sweep(&m, &kn, &[GIB], &opts)[0].cy_per_cl;
        assert!(
            (pts[2].cy_per_cl - nmem).abs() / nmem < 0.06,
            "kahan {} vs naive {} in memory",
            pts[2].cy_per_cl,
            nmem
        );
    }

    #[test]
    fn scalar_kahan_is_flat_and_slow_everywhere() {
        let m = haswell();
        let k = kernel_for(&m, Variant::KahanScalar, Precision::Sp, MemLevel::Mem);
        let pts = sweep(&m, &k, &[16 * KIB, GIB], &MeasureOpts::default());
        assert!(pts[0].cy_per_cl > 180.0, "L1 {}", pts[0].cy_per_cl);
        let ratio = pts[1].cy_per_cl / pts[0].cy_per_cl;
        assert!((0.95..1.1).contains(&ratio), "flat: {ratio}");
    }

    #[test]
    fn noise_is_deterministic() {
        let m = haswell();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let a = sweep(&m, &k, &[GIB], &MeasureOpts::default());
        let b = sweep(&m, &k, &[GIB], &MeasureOpts::default());
        assert_eq!(a[0].cy_per_cl, b[0].cy_per_cl);
    }

    #[test]
    fn pwr8_erratic_window_fluctuates() {
        let m = power8();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let opts = MeasureOpts { smt: 8, untuned: false, seed: 1 };
        // Sample many points inside 2..64 MB and compare spread against
        // points beyond 64 MB.
        let inside: Vec<u64> = (0..12).map(|i| (3 + i) * 4 * MIB).collect();
        let outside: Vec<u64> = (0..6).map(|i| (i + 2) * 128 * MIB).collect();
        let spread = |pts: &[MeasuredPoint]| {
            let v: Vec<f64> = pts.iter().map(|p| p.cy_per_cl).collect();
            let max = v.iter().cloned().fold(f64::MIN, f64::max);
            let min = v.iter().cloned().fold(f64::MAX, f64::min);
            (max - min) / min
        };
        let si = spread(&sweep(&m, &k, &inside, &opts));
        let so = spread(&sweep(&m, &k, &outside, &opts));
        assert!(si > so, "erratic window spread {si} vs outside {so}");
        assert!(si > 0.1, "erratic window should fluctuate: {si}");
    }

    #[test]
    fn pwr8_smt1_breaks_down_in_l1() {
        // Fig. 7a: in L1, more SMT threads = shorter per-thread loops =
        // worse performance; SMT-1 is best.
        let m = power8();
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let ws = 32 * KIB;
        let p1 = sweep(&m, &k, &[ws], &MeasureOpts { smt: 1, untuned: false, seed: 1 })[0].gups;
        let p8 = sweep(&m, &k, &[ws], &MeasureOpts { smt: 8, untuned: false, seed: 1 })[0].gups;
        assert!(p1 > p8, "L1: SMT-1 {p1} must beat SMT-8 {p8}");
    }

    #[test]
    fn default_sizes_span_hierarchy() {
        let sizes = default_sweep_sizes(GIB);
        assert!(sizes.len() > 40);
        assert!(sizes[0] <= 8 * KIB);
        assert!(*sizes.last().unwrap() >= GIB / 2);
    }
}
