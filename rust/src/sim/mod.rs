//! The virtual testbed: a microarchitecture simulator standing in for the
//! paper's physical machines (DESIGN.md §2).
//!
//! Components:
//! * [`core`] — scoreboard port/latency scheduler: produces steady-state
//!   in-core cycles per loop body for L1-resident data (OoO for Xeon/PWR8,
//!   in-order paired issue for KNC, SMT-aware).
//! * [`cache`] — the data-transfer engine: working-set size -> which level
//!   serves the streams -> per-CL transfer cycles, including the inclusive
//!   (Intel) vs victim (POWER8) data paths, prefetch friction and latency
//!   penalties.
//! * [`multicore`] — shared-bandwidth contention, cluster-on-die domains,
//!   and the KNC ring model, producing scaling curves.
//! * [`measure`] — the "likwid-bench" front door: single-core working-set
//!   sweeps and in-memory core scans with deterministic measurement noise.
//!
//! The simulator deliberately does NOT call into the [`crate::ecm`] engine:
//! model-vs-"measurement" comparisons stay non-circular. It shares only the
//! machine description ([`crate::arch`]) and the kernel IR ([`crate::isa`]).

pub mod cache;
pub mod core;
pub mod measure;
pub mod multicore;

pub use self::core::{simulate_core, simulate_core_cached, CoreResult};
pub use cache::{compose, data_cycles, residence, DataCycles, MeasureOpts};
pub use measure::{corescan, default_sweep_sizes, sweep, MeasuredPoint};
pub use multicore::scaling_curve;
