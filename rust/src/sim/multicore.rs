//! Multicore contention: "measured" chip-level scaling (Figs. 8, 9).
//!
//! Differences from the ECM scaling *model* (ecm::scaling):
//! * saturation is smooth (`C·tanh(x/C)`-shaped), reproducing the paper's
//!   observation that "the number of cores required to reach saturation is
//!   underestimated [by the model]" (Sect. 5.1 attributes this to the
//!   documented prefetcher-strategy change near saturation);
//! * on KNC the ring latency grows with the number of active cores, giving
//!   the three-phase piecewise-linear scaling of Fig. 8c (slope changes
//!   near 20 and 50 cores);
//! * cluster-on-die domains are filled round-robin as in the measurement
//!   protocol.

use crate::arch::Machine;
use crate::isa::KernelLoop;

use super::cache::MeasureOpts;

/// Saturated chip ceiling in GUP/s for a kernel's traffic on one domain.
fn domain_ceiling_gups(m: &Machine, k: &KernelLoop) -> f64 {
    // Memory moves `streams` bytes-per-element per update.
    let bytes_per_update = k.bytes_per_update() as f64;
    m.mem.sustained_bw_gbs / bytes_per_update
}

/// KNC ring-latency growth: more active cores = more hops/arbitration.
/// Produces the measured piecewise slope changes at ~20 and ~50 cores.
fn knc_ring_slowdown(n: u32) -> f64 {
    let n = n as f64;
    let extra = 0.006 * (n - 20.0).max(0.0) + 0.01 * (n - 50.0).max(0.0);
    1.0 + extra
}

/// "Measured" scaling curve: chip-level GUP/s for n = 1..=cores, given the
/// single-core in-memory performance `p1_gups` (from a sweep).
pub fn scaling_curve(
    m: &Machine,
    k: &KernelLoop,
    p1_gups: f64,
    _opts: &MeasureOpts,
) -> Vec<(u32, f64)> {
    let domains = m.mem.domains.max(1);
    let ceil = domain_ceiling_gups(m, k);
    (1..=m.cores)
        .map(|n| {
            let base = n / domains;
            let extra = n % domains;
            let mut p = 0.0;
            for d in 0..domains {
                let cores_here = (base + u32::from(d < extra)) as f64;
                let mut p1 = p1_gups;
                if m.shorthand == "KNC" {
                    p1 /= knc_ring_slowdown(n);
                }
                let x = cores_here * p1 / ceil;
                // Smooth-min saturation: linear for x << 1, asymptotic to
                // the ceiling; saturation is reached a core or so later
                // than the ECM model predicts — the paper's observed
                // deviation (Sect. 5.1).
                p += ceil * x / (1.0 + x.powi(6)).powf(1.0 / 6.0);
            }
            (n, p)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::ecm::derive::{kernel_for, MemLevel};
    use crate::isa::Variant;
    use crate::sim::measure::{corescan, MeasureOpts};
    use crate::util::units::{Precision, GIB};

    fn scan(m: &Machine, v: Variant, smt: u32, untuned: bool) -> Vec<(u32, f64)> {
        let k = kernel_for(m, v, Precision::Sp, MemLevel::Mem);
        corescan(m, &k, 10 * GIB, &MeasureOpts { smt, untuned, seed: 1 })
    }

    #[test]
    fn hsw_naive_saturates_near_8_gups() {
        // Fig. 8a: naive/manual-Kahan saturate at ~8 GUP/s per chip.
        let curve = scan(&haswell(), Variant::NaiveSimd, 1, false);
        let last = curve.last().unwrap().1;
        assert!((7.0..8.3).contains(&last), "HSW chip {last}");
        // Saturation reached before the full chip: 10-core value within 5%.
        let p10 = curve[9].1;
        assert!((p10 - last).abs() / last < 0.05, "p10 {p10} vs {last}");
    }

    #[test]
    fn hsw_kahan_manual_equals_naive_at_chip_level() {
        let n = scan(&haswell(), Variant::NaiveSimd, 1, false);
        let k = scan(&haswell(), Variant::KahanSimdFma5, 1, false);
        let (ln, lk) = (n.last().unwrap().1, k.last().unwrap().1);
        assert!((ln - lk).abs() / ln < 0.05, "naive {ln} vs kahan {lk}");
    }

    #[test]
    fn hsw_compiler_kahan_misses_saturation() {
        // Fig. 8a: the compiler Kahan is so slow that 14 cores are far from
        // the bandwidth ceiling.
        let curve = scan(&haswell(), Variant::KahanScalar, 1, false);
        let last = curve.last().unwrap().1;
        let ceil = 8.0;
        assert!(
            last < 0.55 * ceil,
            "compiler Kahan reached {last} of ~{ceil} GUP/s"
        );
        // And scaling is still ~linear at the chip edge.
        let slope_end = curve[13].1 - curve[12].1;
        let slope_start = curve[1].1 - curve[0].1;
        assert!(slope_end > 0.6 * slope_start);
    }

    #[test]
    fn knc_saturates_around_21_gups_with_phases() {
        // Fig. 8c: manual Kahan saturates near 21.3 GUP/s; the curve is
        // piecewise with decreasing slope after ~20 and ~50 cores.
        let m = knights_corner();
        let curve = scan(&m, Variant::KahanSimdFma, 1, false);
        let last = curve.last().unwrap().1;
        assert!((17.0..22.5).contains(&last), "KNC chip {last}");
        let slope = |a: usize, b: usize| (curve[b].1 - curve[a].1) / (b - a) as f64;
        let s1 = slope(2, 15);
        let s2 = slope(25, 45);
        let s3 = slope(52, 58);
        assert!(s1 > s2, "phase1 {s1} vs phase2 {s2}");
        assert!(s2 > s3, "phase2 {s2} vs phase3 {s3}");
    }

    #[test]
    fn knc_compiler_naive_misses_by_far() {
        // Fig. 8c: "the naive compiler version misses it by far" (1-SMT, no
        // software prefetch -> exposed ring latency).
        let curve = scan(&knights_corner(), Variant::NaiveSimd, 1, true);
        let last = curve.last().unwrap().1;
        assert!(last < 0.65 * 21.3, "compiler naive reached {last}");
    }

    #[test]
    fn pwr8_saturates_quickly() {
        // Fig. 8d: naive and Kahan saturate the bandwidth with few cores.
        let m = power8();
        let curve = scan(&m, Variant::KahanSimdFma, 8, false);
        let last = curve.last().unwrap().1;
        assert!((8.0..9.5).contains(&last), "PWR8 chip {last}");
        let p4 = curve[3].1;
        assert!(p4 > 0.9 * last, "4 cores reach {p4} of {last}");
    }

    #[test]
    fn curves_are_monotone() {
        for m in all_machines() {
            let curve = scan(&m, Variant::NaiveSimd, 1, false);
            for w in curve.windows(2) {
                assert!(w[1].1 >= w[0].1 - 1e-9, "{}: {:?}", m.shorthand, w);
            }
        }
    }
}
