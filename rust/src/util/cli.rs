//! Tiny command-line parser (clap is not in the offline crate cache).
//!
//! Model: `prog <subcommand> [positionals] [--flag] [--key value]`.
//! Unknown options are errors; `--help` is synthesized from registered specs.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue {
        key: String,
        value: String,
        why: String,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::BadValue { key, value, why } => {
                write!(f, "invalid value for --{key}: {value} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Specification of accepted options for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    /// (name, takes_value, help)
    pub opts: Vec<(&'static str, bool, &'static str)>,
}

impl Spec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push((name, false, help));
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push((name, true, help));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        for (name, takes, help) in &self.opts {
            if *takes {
                s.push_str(&format!("  --{name} <value>  {help}\n"));
            } else {
                s.push_str(&format!("  --{name}          {help}\n"));
            }
        }
        s
    }

    /// Parse raw args (without program/subcommand) against this spec.
    pub fn parse<I: IntoIterator<Item = String>>(&self, raw: I) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // Support --key=value as well as --key value.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|(n, _, _)| *n == name)
                    .ok_or_else(|| CliError::UnknownOption(name.to_string()))?;
                if spec.1 {
                    let v = match inline {
                        Some(v) => v,
                        None => iter
                            .next()
                            .ok_or_else(|| CliError::MissingValue(name.to_string()))?,
                    };
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new()
            .opt("out-dir", "output directory")
            .opt("seed", "rng seed")
            .flag("verbose", "print more")
    }

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = spec()
            .parse(v(&["fig5a", "--out-dir", "out", "--verbose", "x"]))
            .unwrap();
        assert_eq!(a.positionals, vec!["fig5a", "x"]);
        assert_eq!(a.opt("out-dir"), Some("out"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parse_eq_form() {
        let a = spec().parse(v(&["--seed=42"])).unwrap();
        assert_eq!(a.opt_parse("seed", 0u64).unwrap(), 42);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            spec().parse(v(&["--nope"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            spec().parse(v(&["--seed"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_reported() {
        let a = spec().parse(v(&["--seed", "abc"])).unwrap();
        assert!(matches!(
            a.opt_parse("seed", 0u64),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(v(&[])).unwrap();
        assert_eq!(a.opt_or("out-dir", "out"), "out");
        assert_eq!(a.opt_parse("seed", 7u64).unwrap(), 7);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn help_text_lists_options() {
        let h = spec().help_text();
        assert!(h.contains("--out-dir"));
        assert!(h.contains("--verbose"));
    }
}
