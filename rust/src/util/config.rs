//! Line-oriented `key = value` config format for user-defined machines
//! (the `custom_arch` example). A deliberate TOML subset: sections in
//! `[brackets]`, scalars, comma-separated lists, `#` comments.
//!
//! ```text
//! [machine]
//! name = My Chip
//! freq_ghz = 3.0
//! cores = 8
//!
//! [cache.l1]
//! capacity = 32768
//! bw_bytes_per_cy = 64
//! ```

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// section -> key -> raw value string
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

#[derive(Debug)]
pub enum ConfigError {
    Parse(usize, String),
    MissingSection(String),
    MissingKey(String, String),
    BadValue(String, String, String, &'static str),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(line, msg) => write!(f, "line {line}: {msg}"),
            ConfigError::MissingSection(s) => write!(f, "missing section [{s}]"),
            ConfigError::MissingKey(s, k) => write!(f, "missing key '{k}' in section [{s}]"),
            ConfigError::BadValue(s, k, v, ty) => {
                write!(f, "section [{s}] key '{k}': cannot parse '{v}' as {ty}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::from("");
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::Parse(ln + 1, "unclosed [section]".into()))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                if section.is_empty() {
                    return Err(ConfigError::Parse(ln + 1, "key before any [section]".into()));
                }
                cfg.sections
                    .get_mut(&section)
                    .unwrap()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                return Err(ConfigError::Parse(
                    ln + 1,
                    format!("expected 'key = value' or '[section]', got '{line}'"),
                ));
            }
        }
        Ok(cfg)
    }

    pub fn section(&self, name: &str) -> Result<&BTreeMap<String, String>, ConfigError> {
        self.sections
            .get(name)
            .ok_or_else(|| ConfigError::MissingSection(name.to_string()))
    }

    /// Sections whose name starts with `prefix.` (e.g. all `[cache.*]`),
    /// in file-independent (sorted) order.
    pub fn sections_with_prefix(&self, prefix: &str) -> Vec<(&str, &BTreeMap<String, String>)> {
        let pat = format!("{prefix}.");
        self.sections
            .iter()
            .filter(|(k, _)| k.starts_with(&pat))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<&str, ConfigError> {
        self.section(section)?
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| ConfigError::MissingKey(section.to_string(), key.to_string()))
    }

    pub fn get<T: std::str::FromStr>(&self, section: &str, key: &str) -> Result<T, ConfigError> {
        let raw = self.get_str(section, key)?;
        raw.parse().map_err(|_| {
            ConfigError::BadValue(
                section.to_string(),
                key.to_string(),
                raw.to_string(),
                std::any::type_name::<T>(),
            )
        })
    }

    pub fn get_or<T: std::str::FromStr>(
        &self,
        section: &str,
        key: &str,
        default: T,
    ) -> Result<T, ConfigError> {
        match self.section(section).ok().and_then(|s| s.get(key)) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| {
                ConfigError::BadValue(
                    section.to_string(),
                    key.to_string(),
                    raw.to_string(),
                    std::any::type_name::<T>(),
                )
            }),
        }
    }

    /// Comma-separated list value.
    pub fn get_list(&self, section: &str, key: &str) -> Result<Vec<String>, ConfigError> {
        Ok(self
            .get_str(section, key)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# a machine
[machine]
name = Test Chip
freq_ghz = 2.5
ports = load, load, add  # three ports

[cache.l1]
capacity = 32768
";

    #[test]
    fn parse_sample() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("machine", "name").unwrap(), "Test Chip");
        assert_eq!(c.get::<f64>("machine", "freq_ghz").unwrap(), 2.5);
        assert_eq!(c.get::<u64>("cache.l1", "capacity").unwrap(), 32768);
        assert_eq!(
            c.get_list("machine", "ports").unwrap(),
            vec!["load", "load", "add"]
        );
    }

    #[test]
    fn defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_or("machine", "cores", 4u32).unwrap(), 4);
    }

    #[test]
    fn prefix_sections_sorted() {
        let c = Config::parse("[cache.l2]\na=1\n[cache.l1]\na=2\n[mem]\nb=3\n").unwrap();
        let s = c.sections_with_prefix("cache");
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0, "cache.l1");
        assert_eq!(s[1].0, "cache.l2");
    }

    #[test]
    fn errors() {
        assert!(matches!(
            Config::parse("key = 1"),
            Err(ConfigError::Parse(1, _))
        ));
        assert!(matches!(
            Config::parse("[open\n"),
            Err(ConfigError::Parse(1, _))
        ));
        let c = Config::parse(SAMPLE).unwrap();
        assert!(matches!(
            c.get_str("nope", "x"),
            Err(ConfigError::MissingSection(_))
        ));
        assert!(matches!(
            c.get_str("machine", "nope"),
            Err(ConfigError::MissingKey(_, _))
        ));
        assert!(matches!(
            c.get::<u32>("machine", "name"),
            Err(ConfigError::BadValue(..))
        ));
    }
}
