//! Minimal JSON parser (serde is not in the offline crate cache).
//!
//! Supports the full JSON grammar; used to read `artifacts/manifest.json`
//! and to write experiment result metadata. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; returns None for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

impl fmt::Display for Json {
    /// Serialize (compact).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 1; // consume 'u' position marker below
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("expected low surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("expected \\u low surrogate"));
                                }
                                let lo = self.hex4()?;
                                self.pos += 1;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                self.pos -= 1; // realign: hex4 leaves pos on last digit
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.pos..];
                    let len = utf8_len(c);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = chunk.chars().next().ok_or_else(|| self.err("invalid UTF-8"))?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    /// Parse 4 hex digits following "\u"; on return, pos is at the last digit.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        let start = self.pos + 1;
        if start + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[start..start + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos = start + 3;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""tab\t quote\" uA""#).unwrap();
        assert_eq!(j.as_str(), Some("tab\t quote\" uA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"format":1,"interchange":"hlo-text","artifacts":[
            {"name":"kahan_f32_n4096","file":"kahan_f32_n4096.hlo.txt",
             "variant":"kahan","dtype":"f32","n":4096,"outputs":1,
             "inputs":[{"shape":[4096],"dtype":"f32"}]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("format").unwrap().as_u64(), Some(1));
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("n").unwrap().as_u64(), Some(4096));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_u64(),
            Some(4096)
        );
    }
}
