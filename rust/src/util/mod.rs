//! Shared infrastructure: PRNG, statistics, JSON, CLI parsing, tables,
//! ASCII plotting, units and a tiny config-file format.
//!
//! The offline crate cache lacks `rand`, `serde`, `clap` and friends, so the
//! pieces of them this project needs are implemented here (DESIGN.md §2).

pub mod cli;
pub mod config;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
