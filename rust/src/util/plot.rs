//! ASCII line plots — the repo's gnuplot stand-in for terminal figure
//! previews (the CSV written next to each plot is the machine-readable
//! artifact; these plots are for humans reading the terminal/EXPERIMENTS.md).

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new<S: Into<String>>(name: S, points: Vec<(f64, f64)>) -> Self {
        Self {
            name: name.into(),
            points,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Linear,
    Log2,
    Log10,
}

impl Scale {
    fn fwd(&self, x: f64) -> f64 {
        match self {
            Scale::Linear => x,
            Scale::Log2 => x.log2(),
            Scale::Log10 => x.log10(),
        }
    }
}

/// Render series into a `width` x `height` character grid with axes.
pub fn render(
    series: &[Series],
    width: usize,
    height: usize,
    xscale: Scale,
    yscale: Scale,
    title: &str,
) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        let (fx, fy) = (xscale.fwd(x), yscale.fwd(y));
        xmin = xmin.min(fx);
        xmax = xmax.max(fx);
        ymin = ymin.min(fy);
        ymax = ymax.max(fy);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    // Pad the y-range 5% so extremes don't sit on the frame.
    let ypad = (ymax - ymin) * 0.05;
    let (ymin, ymax) = (ymin - ypad, ymax + ypad);

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            if !(x.is_finite() && y.is_finite()) {
                continue;
            }
            let fx = (xscale.fwd(x) - xmin) / (xmax - xmin);
            let fy = (yscale.fwd(y) - ymin) / (ymax - ymin);
            let col = ((fx * (width - 1) as f64).round() as usize).min(width - 1);
            let row = height - 1 - ((fy * (height - 1) as f64).round() as usize).min(height - 1);
            grid[row][col] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let ylab = |frac: f64| -> f64 {
        let v = ymin + frac * (ymax - ymin);
        match yscale {
            Scale::Linear => v,
            Scale::Log2 => 2f64.powf(v),
            Scale::Log10 => 10f64.powf(v),
        }
    };
    for (i, row) in grid.iter().enumerate() {
        let frac = 1.0 - i as f64 / (height - 1) as f64;
        let label = if i == 0 || i == height - 1 || i == height / 2 {
            format!("{:>10.3} ", ylab(frac))
        } else {
            " ".repeat(11)
        };
        out.push_str(&label);
        out.push('|');
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&" ".repeat(11));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    let xlab = |v: f64| match xscale {
        Scale::Linear => v,
        Scale::Log2 => 2f64.powf(v),
        Scale::Log10 => 10f64.powf(v),
    };
    out.push_str(&format!(
        "{}{:<12.4}{:>width$.4}\n",
        " ".repeat(12),
        xlab(xmin),
        xlab(xmax),
        width = width - 11
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_without_panic() {
        let s = vec![
            Series::new("a", vec![(1.0, 1.0), (2.0, 4.0), (3.0, 9.0)]),
            Series::new("b", vec![(1.0, 2.0), (2.0, 2.0)]),
        ];
        let out = render(&s, 40, 10, Scale::Linear, Scale::Linear, "t");
        assert!(out.contains('*'));
        assert!(out.contains('o'));
        assert!(out.contains("a\n") || out.contains("a"));
        // title + height rows + axis + x-labels + one legend line per series
        assert_eq!(out.lines().count(), 1 + 10 + 1 + 1 + 2);
    }

    #[test]
    fn log_scales_handle_wide_range() {
        let s = vec![Series::new(
            "sweep",
            vec![(1e3, 2.0), (1e6, 8.0), (1e9, 19.2)],
        )];
        let out = render(&s, 60, 12, Scale::Log10, Scale::Linear, "cy/CL");
        assert!(out.contains("sweep"));
    }

    #[test]
    fn empty_series_ok() {
        let out = render(&[], 40, 10, Scale::Linear, Scale::Linear, "nothing");
        assert!(out.contains("(no data)"));
    }

    #[test]
    fn single_point_ok() {
        let s = vec![Series::new("p", vec![(5.0, 5.0)])];
        let out = render(&s, 20, 5, Scale::Linear, Scale::Linear, "one");
        assert!(out.contains('*'));
    }
}
