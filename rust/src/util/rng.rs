//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256** core).
//!
//! Everything stochastic in this repository — simulator noise, property-test
//! case generation, ill-conditioned input construction — flows through these
//! generators so every run is reproducible from a seed.

/// SplitMix64: used to expand a user seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = sm.next_u64();
        }
        // Guard against the all-zero state (probability ~2^-256 anyway).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Choose one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// A stateless, deterministic "hash noise" in [-1, 1] — used by the
/// simulator to give each (experiment, point) a stable pseudo-measurement
/// jitter without threading RNG state through the sweep code.
pub fn hash_noise(key: u64, salt: u64) -> f64 {
    let mut sm = SplitMix64::new(key ^ salt.rotate_left(32));
    let u = sm.next_u64();
    ((u >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) * 2.0 - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.range_u64(5, 7);
            assert!((5..=7).contains(&v));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn hash_noise_stable_and_bounded() {
        assert_eq!(hash_noise(1, 2), hash_noise(1, 2));
        for k in 0..1000 {
            let x = hash_noise(k, 99);
            assert!((-1.0..=1.0).contains(&x));
        }
    }
}
