//! Summary statistics for benchmark samples (the mini-criterion's math).

/// Summary of a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let median = percentile_sorted(&sorted, 50.0);
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            stddev: var.sqrt(),
            mad: percentile_sorted(&devs, 50.0),
        }
    }

    /// Relative spread (stddev/mean); 0 when mean is 0.
    pub fn rel_stddev(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean.abs()
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Least-squares slope and intercept of y over x.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (slope, my - slope * mx)
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 1.5811388).abs() < 1e-6);
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert_eq!(percentile_sorted(&v, 50.0), 2.5);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        let (m, b) = linear_fit(&x, &y);
        assert!((m - 3.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
