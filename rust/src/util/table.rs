//! Markdown / aligned-text table rendering for reports and figure data.

/// A simple column-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            r.len(),
            self.header.len(),
            "row width {} != header width {}",
            r.len(),
            self.header.len()
        );
        self.rows.push(r);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.header, &w));
        s.push('|');
        for wi in &w {
            s.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &w));
        }
        s
    }

    /// Plain aligned text (terminal output).
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        s.push_str(&fmt_row(&self.header, &w));
        s.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &w));
        }
        s
    }

    /// CSV rendering (no quoting needed for our numeric content; commas in
    /// cells are replaced by semicolons defensively).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| c.replace(',', ";");
        let mut s = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }
}

/// Format a float with `digits` significant-looking decimals, trimming
/// trailing zeros (e.g. 19.20 -> "19.2", 8.00 -> "8").
pub fn fnum(x: f64, digits: usize) -> String {
    let s = format!("{:.*}", digits, x);
    if s.contains('.') {
        let t = s.trim_end_matches('0').trim_end_matches('.');
        t.to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(["a", "bb"]);
        t.row(["1", "2"]).row(["333", "4"]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("| a"));
        assert!(lines[1].starts_with("|--"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new(["a"]).row(["1", "2"]);
    }

    #[test]
    fn csv_roundtrip_simple() {
        let mut t = Table::new(["x", "y"]);
        t.row(["1", "2.5"]);
        assert_eq!(t.to_csv(), "x,y\n1,2.5\n");
    }

    #[test]
    fn fnum_trims() {
        assert_eq!(fnum(19.2, 2), "19.2");
        assert_eq!(fnum(8.0, 2), "8");
        assert_eq!(fnum(6.4, 1), "6.4");
        assert_eq!(fnum(26.4001, 1), "26.4");
    }
}
