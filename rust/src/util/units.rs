//! Units and conversions used throughout the model and the reports.
//!
//! The paper's conventions (Sect. 2, 4):
//! * work is measured in **updates** (UP): one scalar loop iteration of the
//!   dot product. 1 UP = 2 flops naive, 5 flops Kahan (1 MUL + 4 ADD).
//! * time is measured in **cycles per cache line** (cy/CL) for single-core
//!   analysis, where one CL is one cache line's worth of iterations
//!   (16 SP / 8 DP on 64-B lines; 32 SP / 16 DP on 128-B lines).
//! * throughput is **GUP/s** = 1e9 updates per second.

/// Bytes per KiB/MiB/GiB (binary).
pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// Floating-point precision of the kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Sp,
    Dp,
}

impl Precision {
    pub fn bytes(&self) -> u64 {
        match self {
            Precision::Sp => 4,
            Precision::Dp => 8,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::Sp => "SP",
            Precision::Dp => "DP",
        }
    }
}

/// Scalar iterations ("updates") per cache line for a given precision.
pub fn updates_per_cl(cacheline_bytes: u64, prec: Precision) -> u64 {
    cacheline_bytes / prec.bytes()
}

/// cycles/CL + frequency -> GUP/s (single core).
pub fn cycles_per_cl_to_gups(cy_per_cl: f64, freq_ghz: f64, updates_per_cl: u64) -> f64 {
    assert!(cy_per_cl > 0.0);
    updates_per_cl as f64 * freq_ghz / cy_per_cl
}

/// GB/s sustained bandwidth -> cycles to move one cache line.
pub fn bw_to_cycles_per_cl(bw_gbs: f64, freq_ghz: f64, cacheline_bytes: u64) -> f64 {
    assert!(bw_gbs > 0.0);
    cacheline_bytes as f64 * freq_ghz / bw_gbs
}

/// Bytes-per-cycle bandwidth -> cycles to move one cache line.
pub fn bpc_to_cycles_per_cl(bytes_per_cy: f64, cacheline_bytes: u64) -> f64 {
    assert!(bytes_per_cy > 0.0);
    cacheline_bytes as f64 / bytes_per_cy
}

/// Human-readable working-set size ("32 KiB", "2.0 MiB", ...).
pub fn fmt_bytes(b: u64) -> String {
    if b >= GIB {
        format!("{:.1} GiB", b as f64 / GIB as f64)
    } else if b >= MIB {
        format!("{:.1} MiB", b as f64 / MIB as f64)
    } else if b >= KIB {
        format!("{:.1} KiB", b as f64 / KIB as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updates_per_cl_matches_paper() {
        // Sect. 2: n_it = 16 for SP on 64-B lines, 32 on POWER8's 128-B lines.
        assert_eq!(updates_per_cl(64, Precision::Sp), 16);
        assert_eq!(updates_per_cl(64, Precision::Dp), 8);
        assert_eq!(updates_per_cl(128, Precision::Sp), 32);
        assert_eq!(updates_per_cl(128, Precision::Dp), 16);
    }

    #[test]
    fn hsw_memory_cycles_match_paper() {
        // Sect. 4.1.1: 64 B/CL * 2.3 GHz / 32.0 GB/s = 4.6 cy/CL.
        let cy = bw_to_cycles_per_cl(32.0, 2.3, 64);
        assert!((cy - 4.6).abs() < 1e-12, "{cy}");
        // BDW: 64 * 2.1 / 32.3 = 4.161... -> paper rounds to 4.2 cy/CL.
        let cy = bw_to_cycles_per_cl(32.3, 2.1, 64);
        assert!((cy - 4.161).abs() < 2e-3, "{cy}");
    }

    #[test]
    fn knc_memory_cycles_match_paper() {
        // Sect. 4.1.2: 64 B/CL * 1.05 GHz / 175 GB/s = 0.384 -> paper's 0.4.
        let cy = bw_to_cycles_per_cl(175.0, 1.05, 64);
        assert!((cy - 0.384).abs() < 1e-3, "{cy}");
    }

    #[test]
    fn pwr8_memory_cycles_match_paper() {
        // Sect. 4.1.3: 128 B/CL * 2.9 GHz / 73.6 GB/s = 5.0 cy/CL (paper
        // uses f = 2.9 GHz in this formula although nominal clock is 2.926).
        let cy = bw_to_cycles_per_cl(73.6, 2.926, 128);
        assert!((cy - 5.09).abs() < 2e-2, "{cy}");
    }

    #[test]
    fn hsw_eq1_performance() {
        // Eq. (1): 16 UP * 2.3 Gcy/s / 19.2 cy = 1.92 GUP/s (memory level).
        let p = cycles_per_cl_to_gups(19.2, 2.3, 16);
        assert!((p - 1.9166).abs() < 1e-3, "{p}");
        let p = cycles_per_cl_to_gups(2.0, 2.3, 16);
        assert!((p - 18.4).abs() < 1e-12);
    }

    #[test]
    fn l1l2_bandwidth_cycles() {
        // HSW: 64 B/cy L2->L1: one CL in 1 cy; two CLs (dot) in 2 cy.
        assert_eq!(bpc_to_cycles_per_cl(64.0, 64), 1.0);
        // KNC: 32 B/cy -> 2 cy per CL.
        assert_eq!(bpc_to_cycles_per_cl(32.0, 64), 2.0);
        // PWR8: 64 B/cy on 128-B lines -> 2 cy per CL.
        assert_eq!(bpc_to_cycles_per_cl(64.0, 128), 2.0);
    }

    #[test]
    fn fmt_bytes_readable() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(32 * KIB), "32.0 KiB");
        assert_eq!(fmt_bytes(35 * MIB / 10 * 10), "35.0 MiB");
        assert_eq!(fmt_bytes(10 * GIB), "10.0 GiB");
    }
}
