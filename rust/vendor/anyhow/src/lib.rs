//! A dependency-free subset of the `anyhow` error-handling API.
//!
//! Vendored so the workspace builds with no registry access (the build
//! environments this repo targets are frequently offline). Implements the
//! pieces the crate actually uses — `Error`, `Result`, `Context`,
//! `anyhow!` / `bail!` / `ensure!` — with the same semantics:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `.context(..)` / `.with_context(..)` wrap errors (and `Option`s) with
//!   a higher-level message;
//! * `{:#}` formats the full cause chain, `{}` only the outermost message;
//! * `{:?}` renders the `Caused by:` list, as returned `main` errors do.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional chain of context messages.
pub struct Error(Repr);

enum Repr {
    Msg(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    Context { msg: String, source: Box<Error> },
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Repr::Msg(message.to_string()))
    }

    /// Create an error from any standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error(Repr::Boxed(Box::new(error)))
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error(Repr::Context {
            msg: context.to_string(),
            source: Box::new(self),
        })
    }

    /// The messages of the chain, outermost first.
    pub fn chain_strings(&self) -> Vec<String> {
        let mut v = Vec::new();
        self.push_chain(&mut v);
        v
    }

    fn push_chain(&self, v: &mut Vec<String>) {
        match &self.0 {
            Repr::Msg(m) => v.push(m.clone()),
            Repr::Boxed(e) => {
                v.push(e.to_string());
                let mut src = e.source();
                while let Some(s) = src {
                    v.push(s.to_string());
                    src = s.source();
                }
            }
            Repr::Context { msg, source } => {
                v.push(msg.clone());
                source.push_chain(v);
            }
        }
    }

    /// The root cause message (innermost of the chain).
    pub fn root_cause(&self) -> String {
        self.chain_strings().pop().unwrap_or_default()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain.first().map(String::as_str).unwrap_or(""))?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (exactly as in anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Attach context to a `Result<T, anyhow::Error>` (re-contexting).
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outermost_only() {
        let e: Error = io_err().into();
        let e = e.context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
    }

    #[test]
    fn alternate_renders_chain() {
        let e = Error::new(io_err()).context("reading").context("loading");
        assert_eq!(format!("{e:#}"), "loading: reading: file missing");
    }

    #[test]
    fn debug_renders_caused_by() {
        let e = Error::new(io_err()).context("outer");
        let s = format!("{e:?}");
        assert!(s.contains("outer"));
        assert!(s.contains("Caused by:"));
        assert!(s.contains("file missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "file missing");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: u32) -> Result<()> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("exactly {} is forbidden", n);
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(format!("{}", fails(12).unwrap_err()), "n too big: 12");
        assert_eq!(format!("{}", fails(3).unwrap_err()), "exactly 3 is forbidden");
        assert_eq!(format!("{}", fails(1).unwrap_err()), "fell through");
    }

    #[test]
    fn root_cause_is_innermost() {
        let e = Error::new(io_err()).context("outer");
        assert_eq!(e.root_cause(), "file missing");
    }
}
