//! Compile-time stub of the `xla` (PJRT) crate surface used by
//! `kahan_ecm::runtime::executor`.
//!
//! The real `xla` crate links the PJRT C API and is not installable in a
//! hermetic build. This stub keeps `--features pjrt` *compiling* on any
//! machine: every entry point returns a descriptive runtime error instead
//! of executing. To actually run the AOT artifacts, point the `xla`
//! dependency of `rust/Cargo.toml` at a real checkout, e.g.
//!
//! ```toml
//! [patch."crates-io"]   # or edit the path dependency directly
//! xla = { path = "/path/to/xla-rs" }
//! ```
//!
//! Callers already treat PJRT as optional (artifact-gated tests skip when
//! the client cannot be constructed), so the stub degrades gracefully.

use std::borrow::Borrow;
use std::fmt;

const STUB_MSG: &str = "the vendored `xla` stub provides no PJRT runtime; \
     substitute a real xla crate to execute AOT artifacts";

/// Error type mirroring `xla::Error` well enough for `anyhow` interop.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>() -> Result<T, Error> {
    Err(Error(STUB_MSG.to_string()))
}

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        stub_err()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Compiled executable (stub: cannot be obtained).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

/// Device buffer (stub: cannot be obtained).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

/// Element types the executor converts to/from.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host literal (stub: constructible, but conversions fail).
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub_err()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_roundtrip_is_blocked() {
        let lit = Literal::vec1(&[1.0f64, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f64>().is_err());
    }
}
