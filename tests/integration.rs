//! Cross-module integration tests: registry -> harness -> outputs, the
//! model-vs-simulator agreement that is the paper's Sect. 5, the native
//! execution-backend path, and (feature `pjrt`) the artifacts -> PJRT ->
//! numerics path.

use kahan_ecm::arch::{all_machines, presets};
use kahan_ecm::coordinator::{all_experiments, find, run_parallel};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::harness::Ctx;
use kahan_ecm::isa::Variant;
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::units::{Precision, GIB};

/// Every registered experiment (except the artifact-dependent ones when
/// artifacts are absent) runs to completion on the quick grid and produces
/// at least one table.
#[test]
fn every_experiment_runs_quick() {
    let have_artifacts = kahan_ecm::runtime::Manifest::load("artifacts").is_ok();
    let defs: Vec<_> = all_experiments()
        .into_iter()
        .filter(|d| have_artifacts || !d.needs_artifacts)
        .collect();
    let ctx = Ctx::quick();
    let outcomes = run_parallel(&defs, &ctx, 2);
    for o in &outcomes {
        let out = o.result.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", o.id));
        assert!(
            !out.tables.is_empty() || !out.plots.is_empty(),
            "{} produced nothing",
            o.id
        );
    }
}

/// Outputs are written to disk with the promised layout.
#[test]
fn outputs_written_to_disk() {
    let tmp = std::env::temp_dir().join(format!("kahan-ecm-int-{}", std::process::id()));
    let defs = find("fig1");
    let outcomes = run_parallel(&defs, &Ctx::quick(), 1);
    let out = outcomes[0].result.as_ref().unwrap();
    out.write(tmp.to_str().unwrap()).unwrap();
    assert!(tmp.join("fig1/summary.md").exists());
    assert!(tmp.join("fig1/scaling.csv").exists());
    std::fs::remove_dir_all(&tmp).ok();
}

/// The Sect. 5 validation: for every machine, the simulated in-memory
/// cy/CL of the manual SIMD kernels is within 15% of the ECM prediction
/// (the paper's Fig. 5-7 agreement), while remaining an independent code
/// path (frictions/noise make exact equality impossible).
#[test]
fn sim_validates_ecm_in_memory() {
    for m in all_machines() {
        for v in [Variant::NaiveSimd, Variant::KahanSimdFma] {
            // KNC naive ECM input assumes prefetch-tuned measurement.
            let smt = match m.shorthand {
                "KNC" => 2,
                "PWR8" => 4, // SMT-4: the paper's best-in-memory setting
                _ => 1,
            };
            let inputs = ecm::derive::paper_row(&m, v, Precision::Sp, MemLevel::Mem);
            let pred = inputs.predict().mem_cycles();
            let k = ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
            let meas = sim::sweep(
                &m,
                &k,
                &[4 * GIB],
                &MeasureOpts { smt, untuned: false, seed: 1 },
            )[0]
                .cy_per_cl;
            let dev = (meas - pred).abs() / pred;
            assert!(
                dev < 0.15,
                "{} {:?}: sim {meas:.2} vs ECM {pred:.2} ({:.0}% off)",
                m.shorthand,
                v,
                dev * 100.0
            );
        }
    }
}

/// In L1 the scoreboard agrees with the ECM T_core within 15% for the
/// throughput-bound kernels on every machine.
#[test]
fn sim_validates_ecm_in_l1() {
    for m in all_machines() {
        let v = Variant::KahanSimd;
        let smt = match m.shorthand {
            "KNC" => 2,
            "PWR8" => 2,
            _ => 1,
        };
        let inputs = ecm::derive::paper_row(&m, v, Precision::Sp, MemLevel::L1);
        let pred = inputs.predict().cycles(0);
        let k = ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::L1);
        let meas = sim::sweep(
            &m,
            &k,
            &[16 * 1024],
            &MeasureOpts { smt, untuned: false, seed: 1 },
        )[0]
            .cy_per_cl;
        // Core efficiency calibration (PWR8 -25%) is part of the measured
        // world; fold it out for the comparison.
        let meas_adj = meas * m.calib.core_efficiency;
        let dev = (meas_adj - pred).abs() / pred;
        assert!(
            dev < 0.2,
            "{}: sim L1 {meas_adj:.2} vs ECM {pred:.2}",
            m.shorthand
        );
    }
}

/// The headline claim, end to end: on every Intel machine the manual SIMD
/// Kahan kernel's simulated in-memory throughput equals the naive kernel's
/// within 5%, while in L1 it costs 2.5-4x more cycles.
#[test]
fn kahan_for_free_in_memory_everywhere() {
    for m in all_machines() {
        let smt = match m.shorthand {
            "KNC" => 2,
            "PWR8" => 8,
            _ => 1,
        };
        let opts = MeasureOpts { smt, untuned: false, seed: 1 };
        let naive = ecm::derive::kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let kahan =
            ecm::derive::kernel_for(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);
        let n_mem = sim::sweep(&m, &naive, &[4 * GIB], &opts)[0].cy_per_cl;
        let k_mem = sim::sweep(&m, &kahan, &[4 * GIB], &opts)[0].cy_per_cl;
        assert!(
            (k_mem - n_mem).abs() / n_mem < 0.06,
            "{}: kahan {k_mem:.2} vs naive {n_mem:.2} in memory",
            m.shorthand
        );
        let n_l1 = sim::sweep(&m, &naive, &[16 * 1024], &opts)[0].cy_per_cl;
        let k_l1 = sim::sweep(&m, &kahan, &[16 * 1024], &opts)[0].cy_per_cl;
        assert!(
            k_l1 / n_l1 > 1.5,
            "{}: kahan must cost more in L1 ({k_l1:.2} vs {n_l1:.2})",
            m.shorthand
        );
    }
}

/// CLI-level machine lookup and custom-config loading agree with presets.
#[test]
fn custom_config_pipeline() {
    use kahan_ecm::arch::loader::{machine_from_config, EXAMPLE_CONFIG};
    let m = machine_from_config(EXAMPLE_CONFIG).unwrap();
    // Full analysis pipeline works on the loaded machine.
    let inputs = ecm::derive::paper_row(&m, Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
    let pred = inputs.predict();
    assert!(pred.mem_cycles() > 0.0);
    // This machine has TWO add ports, so the AVX Kahan is NOT add-bound at
    // 8 cy/CL like Haswell — the blueprint produces genuinely different
    // analysis, not a copy.
    let hsw = presets::haswell();
    let hsw_inputs = ecm::derive::paper_row(&hsw, Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
    assert!(inputs.t_ol < hsw_inputs.t_ol, "{} vs {}", inputs.t_ol, hsw_inputs.t_ol);
}

/// The host experiment runs on the native backend with no artifacts and no
/// PJRT installed — the crate's "builds and measures anywhere" guarantee —
/// and produces the kernel-ladder table with every dot rung present.
#[test]
fn host_experiment_runs_natively() {
    use kahan_ecm::runtime::backend::{Backend, NativeBackend};

    let defs = find("host");
    assert_eq!(defs.len(), 1);
    assert!(!defs[0].needs_artifacts, "host must not require artifacts");
    let outcomes = run_parallel(&defs, &Ctx::quick(), 1);
    let out = outcomes[0].result.as_ref().expect("host experiment failed");
    let (name, table) = &out.tables[0];
    assert_eq!(name, "native");
    // One row per (kernel, size): every supported dot rung shows up.
    let backend = NativeBackend::new();
    for spec in backend.kernels() {
        if spec.class.is_dot() {
            assert!(
                table.rows.iter().any(|r| r[0] == spec.id()),
                "missing ladder rung {spec} in host table"
            );
        }
    }
}

/// Backend selection flows from the experiment context: selecting `native`
/// produces only native tables, and selecting `pjrt` in a build without a
/// usable PJRT runtime produces no tables at all (only an explanatory note)
/// — so a selector regression that degenerates to "always native" fails.
#[test]
fn host_experiment_honors_backend_selector() {
    let defs = find("host");

    let mut ctx = Ctx::quick();
    ctx.backend = "native".into();
    let out = run_parallel(&defs, &ctx, 1)[0].result.as_ref().unwrap().clone();
    assert!(!out.tables.is_empty());
    // The native-only run yields the ladder sweep plus the thread-scaling
    // teaser table, and nothing PJRT-flavored.
    assert!(out.tables.iter().all(|(n, _)| n == "native" || n == "threads"));

    // With the pjrt feature and a real runtime the pjrt-only run may
    // legitimately produce tables; only assert the strict "nothing but a
    // skip note" shape in the hermetic default build.
    #[cfg(not(feature = "pjrt"))]
    {
        ctx.backend = "pjrt".into();
        let out = run_parallel(&defs, &ctx, 1)[0].result.as_ref().unwrap().clone();
        assert!(out.tables.is_empty(), "native ran despite --backend pjrt");
        assert!(!out.notes.is_empty());
    }
}

/// The serving layer end to end through the public API: a mixed-size load
/// run serves every request, splits traffic across both scheduling paths
/// at an explicit crossover, and reports self-consistent aggregates. This
/// is the registry-level `serve` experiment's engine driven directly.
#[test]
fn serving_layer_end_to_end() {
    use kahan_ecm::runtime::backend::ImplStyle;
    use kahan_ecm::serve::{run_load, DotService, LoadMode, MixEntry, ServeConfig, ThresholdMode};

    let service = DotService::new(ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(4096),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    })
    .unwrap();
    let mix = vec![
        MixEntry { n: 512, weight: 0.7 },
        MixEntry { n: 16384, weight: 0.3 },
    ];
    let r = run_load(&service, &mix, 96, 12, LoadMode::Closed, 5).unwrap();
    assert_eq!(r.requests, 96);
    assert_eq!(r.fused + r.sharded, 96);
    assert!(r.fused > 0 && r.sharded > 0, "both paths must carry traffic");
    assert!(r.mflops > 0.0 && r.reqs_per_s > 0.0);
    assert!(r.latency_p50_ns <= r.latency_max_ns);
    let stats = service.stats();
    assert_eq!(stats.requests, 96);
    assert_eq!(stats.fused, r.fused);
    assert_eq!(stats.sharded, r.sharded);
    // The same engine through the asynchronous submission queue: identical
    // request stream, identical traffic split, bit-identical checksum.
    use kahan_ecm::serve::{run_load_async, AsyncDotService, AsyncOptions, OperandPool};
    let pipeline = AsyncDotService::new(
        ServeConfig {
            threads: 2,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(4096),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        },
        AsyncOptions::default(),
    )
    .unwrap();
    let operands = OperandPool::generate(&mix, 5, pipeline.service().pool());
    let qr = run_load_async(&pipeline, &mix, &operands, 96, 20_000.0, 5).unwrap();
    assert_eq!(qr.load.checksum.to_bits(), r.checksum.to_bits());
    assert_eq!((qr.load.fused, qr.load.sharded), (r.fused, r.sharded));
    assert!(qr.max_queue_depth <= qr.queue_depth);
    assert!(qr.pool_utilization > 0.0);
    // The serve experiment is registered and runs off this same engine.
    let defs = find("serve");
    assert_eq!(defs.len(), 1);
    assert!(!defs[0].needs_artifacts);
}

/// The TCP wire front-end end to end through the public API: a loopback
/// `serve-net` round trip is bit-identical to the in-process service at
/// the same thread count — inline dot and sum on both sides of the
/// fused/sharded crossover, a mixed batch answered in submission order,
/// and a stats probe that reflects the traffic.
#[test]
fn wire_front_end_loopback_bit_parity() {
    use kahan_ecm::runtime::backend::{ImplStyle, KernelInput};
    use kahan_ecm::serve::{
        AsyncOptions, DotService, NetServer, ServeConfig, SharedInput, ThresholdMode, WireClient,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1000),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let server = NetServer::bind("127.0.0.1:0", cfg.clone(), AsyncOptions::default()).unwrap();
    let reference = DotService::new(cfg).unwrap();
    let mut client = WireClient::connect(server.local_addr()).unwrap();

    // Straddle the crossover: 8/999 fuse, 1000/4096 shard.
    for n in [8usize, 999, 1000, 4096] {
        let x: Vec<f64> = (0..n).map(|i| 0.25 + (i as f64) * 1e-3).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.0 - (i as f64) * 1e-4).collect();
        let wire = client.dot(&x, &y).unwrap();
        let local = reference.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits(), "dot n = {n}");
        assert_eq!(wire.path, local.path, "dot n = {n}");
        assert_eq!(wire.n, n as u64);
        let wire_sum = client.sum(&x).unwrap();
        let local_sum = reference.submit(&KernelInput::Sum(&x)).unwrap();
        assert_eq!(wire_sum.value.to_bits(), local_sum.value.to_bits(), "sum n = {n}");
    }

    // A batch crossing the threshold comes back in submission order.
    let small = SharedInput::dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
    let big_x: Vec<f64> = (0..2048).map(|i| ((i % 7) as f64) * 0.5).collect();
    let big = SharedInput::dot(&big_x, &big_x);
    let tail = SharedInput::sum(&big_x);
    let results = client.batch(&[small.clone(), big.clone(), tail.clone()]).unwrap();
    assert_eq!(results.len(), 3);
    for (wire, input) in results.iter().zip([&small, &big, &tail]) {
        let local = reference.submit(&input.view()).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
        assert_eq!(wire.path, local.path);
    }

    // The stats probe reflects this client's traffic: 8 inline + 3 batched.
    let stats = client.stats().unwrap();
    assert_eq!(stats.threads, 2);
    assert!(stats.completed >= 11, "completed = {}", stats.completed);
    assert!(stats.enqueued >= stats.completed);
    assert_eq!(client.busy_retries(), 0);
}

/// Hostile bytes on the wire get the PROTOCOL.md treatment: bad magic and
/// a wrong version are answered with a typed error frame and a close
/// (fatal — the stream is no longer frame-aligned), while an unknown
/// opcode gets a typed error and leaves the connection fully usable.
#[test]
fn wire_front_end_rejects_garbage() {
    use std::io::{Read, Write};
    use std::net::TcpStream;

    use kahan_ecm::runtime::backend::ImplStyle;
    use kahan_ecm::serve::codec::{self, ErrorCode, Opcode, Response, HEADER_LEN, VERSION};
    use kahan_ecm::serve::{AsyncOptions, NetServer, ServeConfig, ThresholdMode};

    let cfg = ServeConfig {
        threads: 1,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(100),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let server = NetServer::bind("127.0.0.1:0", cfg, AsyncOptions::default()).unwrap();

    fn read_frame(s: &mut TcpStream) -> (u64, Response) {
        let mut head = [0u8; HEADER_LEN];
        s.read_exact(&mut head).unwrap();
        let h = codec::decode_header(&head).unwrap();
        let mut payload = vec![0u8; h.payload_len as usize];
        s.read_exact(&mut payload).unwrap();
        let op = Opcode::from_byte(h.opcode).expect("response opcode");
        (h.request_id, codec::decode_response(op, &payload).unwrap())
    }
    fn expect_error(resp: Response, code: ErrorCode) {
        match resp {
            Response::Error(e) => assert_eq!(e.code, code, "{}", e.message),
            other => panic!("expected {code:?} error, got {other:?}"),
        }
    }
    fn expect_eof(s: &mut TcpStream) {
        let mut byte = [0u8; 1];
        assert_eq!(s.read(&mut byte).unwrap(), 0, "server must close the stream");
    }

    // Bad magic: typed error (request id unattributable -> 0), then close.
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    let mut frame = codec::encode_stats(9);
    frame[0] = b'X';
    s.write_all(&frame).unwrap();
    let (id, resp) = read_frame(&mut s);
    assert_eq!(id, 0);
    expect_error(resp, ErrorCode::BadMagic);
    expect_eof(&mut s);

    // Wrong version: same fatal treatment.
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    let mut frame = codec::encode_stats(9);
    frame[4] = VERSION + 1;
    s.write_all(&frame).unwrap();
    let (_, resp) = read_frame(&mut s);
    expect_error(resp, ErrorCode::BadVersion);
    expect_eof(&mut s);

    // Unknown opcode: typed error with the offending request id, and the
    // connection keeps serving.
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    let mut frame = codec::encode_stats(5);
    frame[5] = 0x42;
    s.write_all(&frame).unwrap();
    let (id, resp) = read_frame(&mut s);
    assert_eq!(id, 5);
    expect_error(resp, ErrorCode::BadOpcode);
    s.write_all(&codec::encode_sum(6, &[1.0, 2.0, 4.0])).unwrap();
    let (id, resp) = read_frame(&mut s);
    assert_eq!(id, 6);
    match resp {
        Response::Result(r) => assert_eq!(r.value.to_bits(), 7.0f64.to_bits()),
        other => panic!("expected a result, got {other:?}"),
    }
}

/// The wire load generator against a loopback server is bit-identical to
/// the in-process async pipeline at the same seed and thread count — the
/// `serve-bench` wire row's hard parity gate, as a test.
#[test]
fn wire_loadgen_checksum_parity() {
    use kahan_ecm::runtime::backend::ImplStyle;
    use kahan_ecm::serve::{
        run_load_async, run_load_wire, AsyncDotService, AsyncOptions, MixEntry, NetServer,
        OperandPool, ServeConfig, ThresholdMode,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1024),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let mix = vec![
        MixEntry { n: 128, weight: 0.75 },
        MixEntry { n: 2048, weight: 0.25 },
    ];
    let server = NetServer::bind("127.0.0.1:0", cfg.clone(), AsyncOptions::default()).unwrap();
    let fpu = server.service().service().dot_spec().class.flops_per_update();
    let ops = OperandPool::generate(&mix, 3, server.service().service().pool());
    let wire = run_load_wire(
        &server.local_addr().to_string(),
        &mix,
        &ops,
        32,
        1e6,
        2,
        fpu,
        3,
    )
    .unwrap();

    let pipeline = AsyncDotService::new(cfg, AsyncOptions::default()).unwrap();
    let local_ops = OperandPool::generate(&mix, 3, pipeline.service().pool());
    let local = run_load_async(&pipeline, &mix, &local_ops, 32, 1e6, 3).unwrap();
    assert_eq!(
        wire.load.checksum.to_bits(),
        local.load.checksum.to_bits(),
        "wire vs in-process checksum"
    );
    assert_eq!(
        (wire.load.fused, wire.load.sharded),
        (local.load.fused, local.load.sharded)
    );
    assert_eq!(wire.connections, 2);
    assert!(wire.max_queue_depth <= wire.queue_depth);
}

/// Injected socket faults kill exactly one connection, never the server:
/// for each socket-facing failure site, the armed connection surfaces a
/// client-visible error (bounded by a client read timeout — no hangs),
/// the injector confirms the fault fired exactly once, and a fresh
/// connection to the same server serves bit-identical results. The
/// slow-client stall site only delays; its response still arrives intact.
#[test]
fn wire_socket_faults_kill_one_connection_not_the_server() {
    use std::sync::Arc;
    use std::time::Duration;

    use kahan_ecm::runtime::backend::{ImplStyle, KernelInput};
    use kahan_ecm::serve::{
        AsyncOptions, DotService, FaultInjector, FaultPlan, FaultSite, NetOptions, NetServer,
        ServeConfig, SharedInput, ThresholdMode, WireClient,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1024),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let reference = DotService::new(cfg.clone()).unwrap();
    let x: Vec<f64> = (0..512).map(|i| 0.25 + (i as f64) * 1e-3).collect();
    let y: Vec<f64> = (0..512).map(|i| 2.0 - (i as f64) * 1e-4).collect();
    let sites = [
        FaultSite::SocketReadError,
        FaultSite::SocketWriteError,
        FaultSite::TruncatedFrame,
        FaultSite::ConnDropMidBatch,
    ];
    for site in sites {
        let injector = FaultInjector::new(FaultPlan::none().with(site, 1));
        let server = NetServer::bind_with(
            "127.0.0.1:0",
            cfg.clone(),
            AsyncOptions::default(),
            NetOptions {
                faults: Some(Arc::clone(&injector)),
                ..NetOptions::default()
            },
        )
        .unwrap();
        let mut victim = WireClient::connect(server.local_addr()).unwrap();
        // A writer-side death leaves the reader's half of the socket open;
        // the client read timeout turns that into an error, not a hang.
        victim.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let died = if site == FaultSite::ConnDropMidBatch {
            victim
                .batch(&[SharedInput::dot(&x, &y), SharedInput::sum(&x)])
                .is_err()
        } else {
            victim.dot(&x, &y).is_err()
        };
        assert!(died, "{site:?}: the armed connection must surface an error");
        assert_eq!(injector.fired(site), 1, "{site:?} must fire exactly once");
        // The trigger is spent: a fresh connection to the same server
        // serves with in-process-identical bits.
        let mut healthy = WireClient::connect(server.local_addr()).unwrap();
        let wire = healthy.dot(&x, &y).unwrap();
        let local = reference.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits(), "{site:?}");
        assert_eq!(wire.path, local.path, "{site:?}");
    }

    // The slow-client stall only deschedules the writer: the response is
    // late, never lost or corrupted.
    let injector = FaultInjector::new(FaultPlan::none().with_stall(
        FaultSite::SlowClientWriter,
        1,
        Duration::from_millis(50),
    ));
    let server = NetServer::bind_with(
        "127.0.0.1:0",
        cfg,
        AsyncOptions::default(),
        NetOptions {
            faults: Some(Arc::clone(&injector)),
            ..NetOptions::default()
        },
    )
    .unwrap();
    let mut client = WireClient::connect(server.local_addr()).unwrap();
    let wire = client.dot(&x, &y).unwrap();
    let local = reference.submit(&KernelInput::Dot(&x, &y)).unwrap();
    assert_eq!(wire.value.to_bits(), local.value.to_bits());
    assert_eq!(injector.fired(FaultSite::SlowClientWriter), 1);
}

/// A batch carrying an already-expired deadline budget is shed in-queue
/// and answered with one typed DEADLINE error frame; the connection
/// survives, and a generous budget round-trips the same batch with
/// in-process-identical bits (PROTOCOL.md §2.4, §4.10).
#[test]
fn wire_batch_deadline_shed_is_typed_and_nonfatal() {
    use std::time::Duration;

    use kahan_ecm::runtime::backend::ImplStyle;
    use kahan_ecm::serve::codec::ErrorCode;
    use kahan_ecm::serve::{
        AsyncOptions, DotService, NetServer, ServeConfig, SharedInput, ThresholdMode,
        WireCallError, WireClient,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1024),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let server = NetServer::bind("127.0.0.1:0", cfg.clone(), AsyncOptions::default()).unwrap();
    let reference = DotService::new(cfg).unwrap();
    let mut client = WireClient::connect(server.local_addr()).unwrap();
    let x: Vec<f64> = (0..2048).map(|i| 0.5 + (i as f64) * 1e-4).collect();
    let inputs = [SharedInput::dot(&x, &x), SharedInput::sum(&x)];

    match client.batch_with_deadline(&inputs, Duration::ZERO) {
        Err(WireCallError::Server(e)) => assert_eq!(e.code, ErrorCode::Deadline, "{}", e.message),
        other => panic!("expected a typed DEADLINE error frame, got {other:?}"),
    }
    // Non-fatal: the same connection carries the same batch to completion
    // under a generous budget, bit-identical to the in-process service.
    let results = client.batch_with_deadline(&inputs, Duration::from_secs(60)).unwrap();
    assert_eq!(results.len(), 2);
    for (wire, input) in results.iter().zip(&inputs) {
        let local = reference.submit(&input.view()).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
        assert_eq!(wire.path, local.path);
    }
    let shed = server.service().stats().deadline_shed;
    assert!(shed >= 1, "the expired batch must shed in-queue, shed = {shed}");
}

/// End-to-end multi-tenant QoS over a real socket: two clients tag their
/// traffic with different tenant ids against a weighted-fair server,
/// every response is bit-identical to in-process execution (scheduling
/// class never forks the numerics), a quota-0 tenant draws the typed
/// QUOTA frame with a retry-after hint while the others keep serving, and
/// the rev-1.2 tenant stats extension accounts every request exactly once
/// (PROTOCOL.md §2.5, §3.7, §4.11).
#[test]
fn wire_tenants_are_scheduled_fairly_and_accounted_exactly_once() {
    use kahan_ecm::runtime::backend::{ImplStyle, KernelInput};
    use kahan_ecm::serve::codec::ErrorCode;
    use kahan_ecm::serve::{
        AsyncOptions, DotService, NetOptions, NetServer, QosPolicy, ServeConfig, ThresholdMode,
        WireCallError, WireClient,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1024),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let net = NetOptions {
        qos: Some(QosPolicy::parse("gold:3:64,bronze:1:64,blocked:1:0").unwrap()),
        ..NetOptions::default()
    };
    let server =
        NetServer::bind_with("127.0.0.1:0", cfg.clone(), AsyncOptions::default(), net).unwrap();
    let reference = DotService::new(cfg).unwrap();
    let mut gold = WireClient::connect(server.local_addr()).unwrap();
    let mut bronze = WireClient::connect(server.local_addr()).unwrap();

    // Interleaved tagged traffic from both clients: every response must
    // match the in-process service bit-for-bit, fused and sharded alike.
    let sizes = [256usize, 2048, 512, 4096];
    for (k, &n) in sizes.iter().cycle().take(12).enumerate() {
        let x: Vec<f64> = (0..n).map(|i| 0.5 + (i as f64) * 1e-4).collect();
        let y: Vec<f64> = (0..n).map(|i| 1.5 - (i as f64) * 1e-5).collect();
        let (client, tenant) = if k % 4 == 3 { (&mut bronze, 1) } else { (&mut gold, 0) };
        let wire = client.dot_with_tenant(&x, &y, tenant).unwrap();
        let local = reference.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits(), "tenant {tenant}, n={n}");
        assert_eq!(wire.path, local.path, "tenant {tenant}, n={n}");
    }

    // The quota-0 tenant sheds with the typed QUOTA frame (distinct from
    // BUSY) and a retry-after hint; the connection survives the shed.
    let x: Vec<f64> = (0..256).map(|i| 0.25 + (i as f64) * 1e-3).collect();
    match bronze.dot_with_tenant(&x, &x, 2) {
        Err(WireCallError::Server(e)) => {
            assert_eq!(e.code, ErrorCode::Quota);
            assert!(e.retry_after_us.unwrap_or(0) > 0, "QUOTA must carry a retry hint");
        }
        other => panic!("expected a typed QUOTA frame, got {other:?}"),
    }
    bronze.dot_with_tenant(&x, &x, 1).unwrap();

    // The tenant stats extension accounts every request exactly once.
    let (_, rows) = gold.stats_tenants(0).unwrap();
    let row = |t: u32| rows.iter().find(|r| r.tenant == t).copied().unwrap();
    assert_eq!(row(0).admitted, 9);
    assert_eq!(row(1).admitted, 4);
    assert_eq!(row(0).completed, 9, "gold traffic fully retires");
    assert_eq!(row(1).completed, 4, "bronze traffic fully retires");
    assert_eq!(row(2).admitted, 0);
    assert_eq!(row(2).quota_shed, 1, "the shed is counted exactly once");
    assert_eq!(row(0).quota_shed + row(1).quota_shed, 0);
}

/// The revision-1.3 resident-operand lifecycle over a real socket, under
/// a tenant QoS policy: REGISTER is content-addressed and server-global
/// (a second connection re-registering the same bits gets the same
/// handle, not fresh), DOT_HANDLES is bit-identical to the in-process
/// service on cache misses and hits alike, hits are attributed to the
/// submitting tenant, RELEASE is idempotent and surfaces the typed
/// non-fatal UNKNOWN_HANDLE on later submits, and re-registering restores
/// the handle with its memoized result replayed bit-exactly.
#[test]
fn wire_operand_store_round_trip_under_tenant_qos() {
    use kahan_ecm::runtime::backend::{ImplStyle, KernelInput};
    use kahan_ecm::serve::codec::{ErrorCode, RequestMeta};
    use kahan_ecm::serve::{
        AsyncOptions, DotService, NetOptions, NetServer, QosPolicy, ServeConfig, ThresholdMode,
        WireCallError, WireClient,
    };

    let cfg = ServeConfig {
        threads: 2,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(1024),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let net = NetOptions {
        qos: Some(QosPolicy::parse("gold:3:64,bronze:1:64").unwrap()),
        ..NetOptions::default()
    };
    let server =
        NetServer::bind_with("127.0.0.1:0", cfg.clone(), AsyncOptions::default(), net).unwrap();
    let reference = DotService::new(cfg).unwrap();
    let mut gold = WireClient::connect(server.local_addr()).unwrap();
    let mut bronze = WireClient::connect(server.local_addr()).unwrap();

    // A catalog straddling the crossover: 256/512 fuse, 2048 shards.
    let catalog: Vec<(Vec<f64>, Vec<f64>)> = [256usize, 2048, 512]
        .iter()
        .map(|&n| {
            let x: Vec<f64> = (0..n).map(|i| 0.5 + (i as f64) * 1e-4).collect();
            let y: Vec<f64> = (0..n).map(|i| 1.5 - (i as f64) * 1e-5).collect();
            (x, y)
        })
        .collect();

    // Register once (gold). Registration is content-addressed: the same
    // bits re-registered — from any connection — return the same handle,
    // not fresh.
    let handles: Vec<(u64, u64)> = catalog
        .iter()
        .map(|(x, y)| {
            let (a, an, fresh_a) = gold.register(x).unwrap();
            let (b, bn, fresh_b) = gold.register(y).unwrap();
            assert!(fresh_a && fresh_b);
            assert_eq!((an as usize, bn as usize), (x.len(), y.len()));
            assert_ne!(a, b, "distinct contents, distinct handles");
            (a, b)
        })
        .collect();
    let (a0, n0, fresh) = bronze.register(&catalog[0].0).unwrap();
    assert_eq!(a0, handles[0].0, "the store is server-global, content-addressed");
    assert_eq!(n0 as usize, catalog[0].0.len());
    assert!(!fresh, "already resident");

    // Miss pass (gold, tenant 0): computed through the queue,
    // bit-identical to the in-process reference.
    let mut want = Vec::new();
    for ((x, y), &(a, b)) in catalog.iter().zip(&handles) {
        let wire = gold.dot_handles(a, b).unwrap();
        let local = reference.submit(&KernelInput::Dot(x, y)).unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits(), "miss n={}", x.len());
        assert_eq!(wire.path, local.path, "miss path n={}", x.len());
        assert_eq!(wire.n as usize, x.len());
        want.push(wire);
    }

    // Hit pass (bronze, tenant 1): served from the result cache,
    // bit-identical across the socket — including the path byte.
    for (w, &(a, b)) in want.iter().zip(&handles) {
        let meta = RequestMeta { tenant: Some(1), ..RequestMeta::default() };
        let hit = bronze.dot_handles_with_meta(a, b, meta).unwrap();
        assert_eq!(hit.value.to_bits(), w.value.to_bits(), "cached bits replay exactly");
        assert_eq!(hit.path, w.path, "the execution path replays too");
    }
    // One more hit on the gold connection (tenant 0).
    let again = gold.dot_handles(handles[0].0, handles[0].1).unwrap();
    assert_eq!(again.value.to_bits(), want[0].value.to_bits());

    // RELEASE is idempotent; a released handle is a typed, non-fatal
    // UNKNOWN_HANDLE on submit (resolution decides liveness — the
    // still-memoized result must not resurrect it) and the connection
    // survives.
    assert!(bronze.release(handles[0].0).unwrap());
    assert!(!bronze.release(handles[0].0).unwrap(), "second release is a no-op");
    match gold.dot_handles(handles[0].0, handles[0].1) {
        Err(WireCallError::Server(e)) => assert_eq!(e.code, ErrorCode::UnknownHandle),
        other => panic!("expected a typed UNKNOWN_HANDLE frame, got {other:?}"),
    }
    // Re-registering the same contents restores the same handle, and the
    // memoized result replays bit-exactly.
    let (re, _, fresh) = gold.register(&catalog[0].0).unwrap();
    assert_eq!(re, handles[0].0, "content-derived handles are stable");
    assert!(fresh, "release made the slot fresh again");
    let replay = gold.dot_handles(handles[0].0, handles[0].1).unwrap();
    assert_eq!(replay.value.to_bits(), want[0].value.to_bits());

    // The stats extension accounts the whole lifecycle exactly: 3 misses
    // (the computed pass), 5 hits (3 bronze + 2 gold), the conservation
    // partition, and per-tenant attribution of the hits.
    let (_, rows, cache) = gold.stats_cache(Some(0)).unwrap();
    assert_eq!(cache.cache_misses, 3);
    assert_eq!(cache.cache_hits, 5);
    assert_eq!(cache.cache_hits + cache.cache_misses, cache.cache_lookups);
    assert_eq!(cache.store_registered, 7, "6 catalog operands + 1 re-register");
    assert_eq!(cache.store_entries, 6);
    assert_eq!(cache.store_resident_bytes, 8 * 2 * (256 + 2048 + 512));
    assert_eq!(cache.store_evictions, 0);
    let row = |t: u32| rows.iter().find(|r| r.tenant == t).copied().unwrap();
    assert_eq!(row(0).admitted, 5, "3 computed + 2 hits on the gold tenant");
    assert_eq!(row(0).completed, 5, "hits count as completed, exactly once");
    assert_eq!(row(1).admitted, 3);
    assert_eq!(row(1).completed, 3, "bronze's cache hits retire exactly once");

    // Plain payload traffic still works on both connections afterwards.
    let x = &catalog[2].0;
    let wire = gold.dot(x, x).unwrap();
    let local = reference.submit(&KernelInput::Dot(x, x)).unwrap();
    assert_eq!(wire.value.to_bits(), local.value.to_bits());
    let wire = bronze.dot(x, x).unwrap();
    assert_eq!(wire.value.to_bits(), local.value.to_bits());
}

/// The wire load generator's wall-clock watchdog: against a server that
/// answers stats probes but swallows every dot request, the run fails
/// with a diagnostic watchdog error — it must never hang CI.
#[test]
fn wire_loadgen_watchdog_fails_fast_on_a_wedged_server() {
    use std::io::{Read, Write};
    use std::net::TcpListener;
    use std::time::Duration;

    use kahan_ecm::runtime::backend::ImplStyle;
    use kahan_ecm::serve::codec::{self, Opcode, WireStats, HEADER_LEN};
    use kahan_ecm::serve::loadgen::run_load_wire_bounded;
    use kahan_ecm::serve::{DotService, MixEntry, OperandPool, ServeConfig, ThresholdMode};

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        // Exactly two connections arrive: the stats probe, then the one
        // load connection.
        for _ in 0..2 {
            let Ok((stream, _)) = listener.accept() else { return };
            std::thread::spawn(move || {
                let mut reader = match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                let mut writer = stream;
                loop {
                    let mut head = [0u8; HEADER_LEN];
                    if reader.read_exact(&mut head).is_err() {
                        return;
                    }
                    let Ok(header) = codec::decode_header(&head) else { return };
                    let mut payload = vec![0u8; header.payload_len as usize];
                    if header.payload_len > 0 && reader.read_exact(&mut payload).is_err() {
                        return;
                    }
                    // Answer stats probes; swallow everything else.
                    if Opcode::from_byte(header.opcode) == Some(Opcode::Stats) {
                        let frame =
                            codec::encode_stats_result(header.request_id, &WireStats::default());
                        if writer.write_all(&frame).is_err() {
                            return;
                        }
                    }
                }
            });
        }
    });

    let cfg = ServeConfig {
        threads: 1,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(4096),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let mix = vec![MixEntry { n: 256, weight: 1.0 }];
    let pool_owner = DotService::new(cfg).unwrap();
    let operands = OperandPool::generate(&mix, 7, pool_owner.pool());
    let err = run_load_wire_bounded(
        &addr.to_string(),
        &mix,
        &operands,
        8,
        1e5,
        1,
        4,
        7,
        Duration::from_secs(2),
    )
    .expect_err("a wedged server must trip the watchdog, not hang");
    assert!(
        err.to_string().contains("watchdog"),
        "diagnostic must name the watchdog: {err}"
    );
}

/// Artifact -> PJRT -> numerics, on adversarial cancellation data (skips
/// cleanly without artifacts or without a real PJRT runtime).
///
/// Construction: thousands of O(1) values plus one +M/-M pair placed so the
/// huge values cancel only at the *root* of any (tree or sequential)
/// reduction — every intermediate partial sits at magnitude M, where one
/// f32 ulp is ~1 and the naive kernel discards most of each O(1) addend.
/// The compensated kernel carries the lost parts in `c` / the fold's
/// residuals and recovers the small sum.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_kahan_beats_naive_on_cancellation() {
    use kahan_ecm::accuracy::exact::exact_dot_f32;
    use kahan_ecm::runtime::{Executor, Manifest};
    use kahan_ecm::util::rng::Rng;

    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let Ok(mut ex) = Executor::new(manifest) else { return };
    let mut rng = Rng::new(2016);
    let (mut total_naive, mut total_kahan) = (0.0f64, 0.0f64);
    const TRIALS: usize = 5;
    const M: f32 = 1.6e7; // ulp(M) = 2 in f32
    for _ in 0..TRIALS {
        let n = 4096;
        let mut xf: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let yf: Vec<f32> = vec![1.0; n];
        xf[0] = M;
        xf[n / 2] = -M;
        let exact = exact_dot_f32(&xf, &yf);
        let xd: Vec<f64> = xf.iter().map(|&v| v as f64).collect();
        let yd: Vec<f64> = yf.iter().map(|&v| v as f64).collect();
        let out = ex.run("pair_f32_n4096", &[&xd, &yd]).unwrap();
        total_naive += (out.outputs[0][0] - exact).abs();
        total_kahan += (out.outputs[1][0] - exact).abs();
    }
    assert!(
        total_kahan < 0.2 * total_naive,
        "kahan {total_kahan:.3e} must beat naive {total_naive:.3e} decisively"
    );
}
