//! Property-based tests on system invariants (the coordinator/model/sim
//! contracts), via the in-repo `ptest` framework.

use kahan_ecm::arch::{all_machines, haswell};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::variants::{build, build_sched, Sched, Variant};
use kahan_ecm::isa::OpClass;
use kahan_ecm::ptest::property;
use kahan_ecm::sim::{self, simulate_core, MeasureOpts};
use kahan_ecm::util::units::Precision;

const VARIANTS: [Variant; 5] = [
    Variant::NaiveSimd,
    Variant::KahanScalar,
    Variant::KahanSimd,
    Variant::KahanSimdFma,
    Variant::KahanSimdFma5,
];

/// Kernel builder invariants over random (variant, lanes, unroll).
#[test]
fn kernel_builder_invariants() {
    property("kernel builder invariants", 120, |g| {
        let v = *g.choose(&VARIANTS);
        let lanes = *g.choose(&[1u32, 2, 4, 8, 16]);
        let unroll = g.u64(1, 12) as u32;
        let sched = if g.bool() { Sched::StageMajor } else { Sched::SoftwarePipelined };
        let k = build_sched(v, lanes, unroll, Precision::Sp, &[], sched);
        k.validate().unwrap();
        assert_eq!(k.updates_per_body, lanes as u64 * unroll as u64);
        // 2 loads per chain, constant per variant.
        assert_eq!(k.count(|o| *o == OpClass::Load), 2 * unroll as usize);
        // Kahan variants carry (s, c) per chain; naive carries acc per chain.
        // Software-pipelined bodies also carry the load targets (loads are
        // hoisted across the loop edge — Fig. 4's next-iteration loads).
        let carried = k.carried_regs().len();
        let per_chain = match (v, sched) {
            (Variant::NaiveSimd, Sched::StageMajor) => 1,
            (Variant::NaiveSimd, Sched::SoftwarePipelined) => 3,
            (_, Sched::StageMajor) => 2,
            (_, Sched::SoftwarePipelined) => 4,
        };
        assert_eq!(carried, per_chain * unroll as usize, "{v:?} {sched:?}");
        // Arithmetic counts: naive 1 FMA/chain; kahan 5 flop-ops per chain
        // encoded as {1 mul + 4 add | 1 fma + 3 add | 2 fma + 2 add}.
        let arith = k.count(|o| o.is_arith());
        match v {
            Variant::NaiveSimd => assert_eq!(arith, unroll as usize),
            Variant::KahanScalar | Variant::KahanSimd => assert_eq!(arith, 5 * unroll as usize),
            _ => assert_eq!(arith, 4 * unroll as usize),
        }
    });
}

/// ECM predictions are monotone non-decreasing with hierarchy depth, and
/// performance conversion preserves ordering.
#[test]
fn ecm_monotone_over_levels() {
    let machines = all_machines();
    property("ECM monotone over levels", 80, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let prec = if g.bool() { Precision::Sp } else { Precision::Dp };
        let inputs = ecm::derive::paper_row(m, v, prec, MemLevel::Mem);
        let pred = inputs.predict();
        let mut last = 0.0;
        for (name, cy) in &pred.levels {
            assert!(
                *cy >= last - 1e-12,
                "{} {:?}: {name} {cy} < previous {last}",
                m.shorthand,
                v
            );
            last = *cy;
        }
        // GUP/s ordering is the inverse.
        let perf = pred.performance_gups(m.freq_ghz);
        for w in perf.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
    });
}

/// Saturation algebra: n_s = ceil(sigma); P at saturation equals the
/// bandwidth bound; the scaling curve is monotone and capped.
#[test]
fn saturation_consistency() {
    let machines = all_machines();
    property("saturation consistency", 60, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let inputs = ecm::derive::paper_row(m, v, Precision::Sp, MemLevel::Mem);
        let sat = ecm::scaling::saturation(m, &inputs);
        assert_eq!(sat.n_s, sat.sigma.ceil() as u32);
        assert!(sat.p_single <= sat.p_sat_domain * 1.0000001);
        let curve = ecm::scaling::scaling_curve(m, &inputs);
        let mut last = 0.0;
        for &(_, p) in &curve {
            assert!(p >= last - 1e-9);
            assert!(p <= sat.p_sat_chip + 1e-9);
            last = p;
        }
    });
}

/// Scoreboard legality: simulated throughput never beats the analytic
/// resource bounds (port pressure is a hard floor), and SMT never reduces
/// aggregate throughput for throughput-bound kernels.
#[test]
fn scoreboard_respects_resource_bounds() {
    let machines = all_machines();
    property("scoreboard >= ResMII", 25, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, MemLevel::Mem);
        let r = simulate_core(m, &k, 1);
        // Floor: arithmetic ops / total arithmetic throughput.
        let arith = k.count(|o| o.is_arith()) as f64;
        let ports = m
            .ports
            .iter()
            .filter(|p| p.caps.iter().any(|c| c.is_arith()))
            .count() as f64;
        let floor = arith / ports / k.cachelines_per_body(m.cacheline);
        assert!(
            r.cycles_per_cl >= floor * 0.999,
            "{} {:?}: sim {} beats floor {floor}",
            m.shorthand,
            v,
            r.cycles_per_cl
        );
    });
}

/// The cache engine: residence weights always form a distribution, and
/// measured cycles grow (weakly) with working-set size at fixed protocol.
#[test]
fn cache_engine_monotonicity() {
    let machines = all_machines();
    property("sweep monotone in ws", 40, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&[Variant::NaiveSimd, Variant::KahanSimdFma]);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, MemLevel::Mem);
        let smt = *g.choose(&[1u32, 2]);
        let base = g.u64(8 * 1024, 64 * 1024);
        // Geometric ladder of sizes; noise is seeded per-point so compare
        // the noise-free trend by averaging adjacent pairs.
        let sizes: Vec<u64> = (0..6).map(|i| base << (2 * i)).collect();
        let pts = sim::sweep(m, &k, &sizes, &MeasureOpts { smt, untuned: false, seed: 0 });
        for w in pts.windows(2) {
            // Within a machine's documented erratic window (PWR8 2-64 MB,
            // Sect. 5.3) fluctuations are the *modeled* behavior; allow a
            // larger dip there.
            let in_erratic = m
                .calib
                .erratic_window
                .map(|(lo, hi, _)| {
                    (w[0].ws_bytes >= lo && w[0].ws_bytes <= hi)
                        || (w[1].ws_bytes >= lo && w[1].ws_bytes <= hi)
                })
                .unwrap_or(false);
            let floor = if in_erratic { 0.70 } else { 0.93 };
            assert!(
                w[1].cy_per_cl >= w[0].cy_per_cl * floor,
                "{}: {} -> {} cy/CL when growing ws {} -> {}",
                m.shorthand,
                w[0].cy_per_cl,
                w[1].cy_per_cl,
                w[0].ws_bytes,
                w[1].ws_bytes
            );
        }
    });
}

/// residence() is a probability distribution for arbitrary sizes.
#[test]
fn residence_distribution_property() {
    let machines = all_machines();
    property("residence sums to 1", 200, |g| {
        let m = g.choose(&machines);
        let ws = g.u64(64, 1 << 36);
        let w = sim::residence(m, ws);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
        assert!(w.iter().all(|&x| (-1e-12..=1.0 + 1e-12).contains(&x)));
    });
}

/// DP vs SP: same in-core cycle cost per CL for SIMD variants (the paper's
/// Sect. 4 observation), exactly half the updates.
#[test]
fn dp_sp_relationship() {
    property("DP = SP cycles, half work", 40, |g| {
        let machines = all_machines();
        let m = g.choose(&machines);
        let v = *g.choose(&[Variant::KahanSimd, Variant::KahanSimdFma, Variant::NaiveSimd]);
        let sp = ecm::derive::paper_row(m, v, Precision::Sp, MemLevel::Mem);
        let dp = ecm::derive::paper_row(m, v, Precision::Dp, MemLevel::Mem);
        assert_eq!(sp.updates_per_cl, 2 * dp.updates_per_cl);
        assert!((sp.t_ol - dp.t_ol).abs() < 1e-9, "{} vs {}", sp.t_ol, dp.t_ol);
    });
}

/// Mov elimination: adding redundant movs to a body never changes the OoO
/// steady state (they are renamed away).
#[test]
fn movs_are_free_on_ooo() {
    let m = haswell();
    property("renamed movs are free", 20, |g| {
        let unroll = g.u64(2, 6) as u32;
        let k = build(Variant::KahanSimd, 8, unroll, Precision::Sp, &[]);
        let base = simulate_core(&m, &k, 1).cycles_per_body;
        let mut k2 = k.clone();
        // Duplicate the trailing movs.
        let movs: Vec<_> = k2
            .body
            .iter()
            .filter(|i| i.op == OpClass::Mov)
            .cloned()
            .collect();
        k2.body.extend(movs);
        let with = simulate_core(&m, &k2, 1).cycles_per_body;
        assert!(
            (with - base).abs() < 0.51,
            "movs changed II: {base} -> {with}"
        );
    });
}
