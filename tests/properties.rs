//! Property-based tests on system invariants (the coordinator/model/sim
//! contracts and the execution-backend parity guarantees), via the in-repo
//! `ptest` framework.

use kahan_ecm::accuracy::generator::ill_conditioned_dot;
use kahan_ecm::arch::{all_machines, haswell};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::variants::{build, build_sched, Sched, Variant};
use kahan_ecm::isa::OpClass;
use kahan_ecm::ptest::property;
use kahan_ecm::runtime::arena::{ALIGN, AlignedVec};
use kahan_ecm::runtime::backend::{
    native, Backend, BackendError, ImplStyle, KernelClass, KernelInput, KernelSpec, NativeBackend,
};
use kahan_ecm::runtime::parallel::{
    compensated_tree_reduce, CACHELINE_F64, ParallelBackend, ThreadPool,
};
use kahan_ecm::serve::{
    handle_of, operand_digest, AsyncDotService, AsyncOptions, DotService, ExecPath, FaultInjector,
    FaultPlan, FaultSite, OperandStore, ServeConfig, SharedInput, ThresholdMode,
};
use kahan_ecm::sim::{self, simulate_core, MeasureOpts};
use kahan_ecm::util::rng::Rng;
use kahan_ecm::util::units::Precision;

const VARIANTS: [Variant; 5] = [
    Variant::NaiveSimd,
    Variant::KahanScalar,
    Variant::KahanSimd,
    Variant::KahanSimdFma,
    Variant::KahanSimdFma5,
];

/// Kernel builder invariants over random (variant, lanes, unroll).
#[test]
fn kernel_builder_invariants() {
    property("kernel builder invariants", 120, |g| {
        let v = *g.choose(&VARIANTS);
        let lanes = *g.choose(&[1u32, 2, 4, 8, 16]);
        let unroll = g.u64(1, 12) as u32;
        let sched = if g.bool() { Sched::StageMajor } else { Sched::SoftwarePipelined };
        let k = build_sched(v, lanes, unroll, Precision::Sp, &[], sched);
        k.validate().unwrap();
        assert_eq!(k.updates_per_body, lanes as u64 * unroll as u64);
        // 2 loads per chain, constant per variant.
        assert_eq!(k.count(|o| *o == OpClass::Load), 2 * unroll as usize);
        // Kahan variants carry (s, c) per chain; naive carries acc per chain.
        // Software-pipelined bodies also carry the load targets (loads are
        // hoisted across the loop edge — Fig. 4's next-iteration loads).
        let carried = k.carried_regs().len();
        let per_chain = match (v, sched) {
            (Variant::NaiveSimd, Sched::StageMajor) => 1,
            (Variant::NaiveSimd, Sched::SoftwarePipelined) => 3,
            (_, Sched::StageMajor) => 2,
            (_, Sched::SoftwarePipelined) => 4,
        };
        assert_eq!(carried, per_chain * unroll as usize, "{v:?} {sched:?}");
        // Arithmetic counts: naive 1 FMA/chain; kahan 5 flop-ops per chain
        // encoded as {1 mul + 4 add | 1 fma + 3 add | 2 fma + 2 add}.
        let arith = k.count(|o| o.is_arith());
        match v {
            Variant::NaiveSimd => assert_eq!(arith, unroll as usize),
            Variant::KahanScalar | Variant::KahanSimd => assert_eq!(arith, 5 * unroll as usize),
            _ => assert_eq!(arith, 4 * unroll as usize),
        }
    });
}

/// ECM predictions are monotone non-decreasing with hierarchy depth, and
/// performance conversion preserves ordering.
#[test]
fn ecm_monotone_over_levels() {
    let machines = all_machines();
    property("ECM monotone over levels", 80, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let prec = if g.bool() { Precision::Sp } else { Precision::Dp };
        let inputs = ecm::derive::paper_row(m, v, prec, MemLevel::Mem);
        let pred = inputs.predict();
        let mut last = 0.0;
        for (name, cy) in &pred.levels {
            assert!(
                *cy >= last - 1e-12,
                "{} {:?}: {name} {cy} < previous {last}",
                m.shorthand,
                v
            );
            last = *cy;
        }
        // GUP/s ordering is the inverse.
        let perf = pred.performance_gups(m.freq_ghz);
        for w in perf.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
    });
}

/// Saturation algebra: n_s = ceil(sigma); P at saturation equals the
/// bandwidth bound; the scaling curve is monotone and capped.
#[test]
fn saturation_consistency() {
    let machines = all_machines();
    property("saturation consistency", 60, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let inputs = ecm::derive::paper_row(m, v, Precision::Sp, MemLevel::Mem);
        let sat = ecm::scaling::saturation(m, &inputs);
        assert_eq!(sat.n_s, sat.sigma.ceil() as u32);
        assert!(sat.p_single <= sat.p_sat_domain * 1.0000001);
        let curve = ecm::scaling::scaling_curve(m, &inputs);
        let mut last = 0.0;
        for &(_, p) in &curve {
            assert!(p >= last - 1e-9);
            assert!(p <= sat.p_sat_chip + 1e-9);
            last = p;
        }
    });
}

/// Scoreboard legality: simulated throughput never beats the analytic
/// resource bounds (port pressure is a hard floor), and SMT never reduces
/// aggregate throughput for throughput-bound kernels.
#[test]
fn scoreboard_respects_resource_bounds() {
    let machines = all_machines();
    property("scoreboard >= ResMII", 25, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&VARIANTS);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, MemLevel::Mem);
        let r = simulate_core(m, &k, 1);
        // Floor: arithmetic ops / total arithmetic throughput.
        let arith = k.count(|o| o.is_arith()) as f64;
        let ports = m
            .ports
            .iter()
            .filter(|p| p.caps.iter().any(|c| c.is_arith()))
            .count() as f64;
        let floor = arith / ports / k.cachelines_per_body(m.cacheline);
        assert!(
            r.cycles_per_cl >= floor * 0.999,
            "{} {:?}: sim {} beats floor {floor}",
            m.shorthand,
            v,
            r.cycles_per_cl
        );
    });
}

/// The cache engine: residence weights always form a distribution, and
/// measured cycles grow (weakly) with working-set size at fixed protocol.
#[test]
fn cache_engine_monotonicity() {
    let machines = all_machines();
    property("sweep monotone in ws", 40, |g| {
        let m = g.choose(&machines);
        let v = *g.choose(&[Variant::NaiveSimd, Variant::KahanSimdFma]);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, MemLevel::Mem);
        let smt = *g.choose(&[1u32, 2]);
        let base = g.u64(8 * 1024, 64 * 1024);
        // Geometric ladder of sizes; noise is seeded per-point so compare
        // the noise-free trend by averaging adjacent pairs.
        let sizes: Vec<u64> = (0..6).map(|i| base << (2 * i)).collect();
        let pts = sim::sweep(m, &k, &sizes, &MeasureOpts { smt, untuned: false, seed: 0 });
        for w in pts.windows(2) {
            // Within a machine's documented erratic window (PWR8 2-64 MB,
            // Sect. 5.3) fluctuations are the *modeled* behavior; allow a
            // larger dip there.
            let in_erratic = m
                .calib
                .erratic_window
                .map(|(lo, hi, _)| {
                    (w[0].ws_bytes >= lo && w[0].ws_bytes <= hi)
                        || (w[1].ws_bytes >= lo && w[1].ws_bytes <= hi)
                })
                .unwrap_or(false);
            let floor = if in_erratic { 0.70 } else { 0.93 };
            assert!(
                w[1].cy_per_cl >= w[0].cy_per_cl * floor,
                "{}: {} -> {} cy/CL when growing ws {} -> {}",
                m.shorthand,
                w[0].cy_per_cl,
                w[1].cy_per_cl,
                w[0].ws_bytes,
                w[1].ws_bytes
            );
        }
    });
}

/// residence() is a probability distribution for arbitrary sizes.
#[test]
fn residence_distribution_property() {
    let machines = all_machines();
    property("residence sums to 1", 200, |g| {
        let m = g.choose(&machines);
        let ws = g.u64(64, 1 << 36);
        let w = sim::residence(m, ws);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
        assert!(w.iter().all(|&x| (-1e-12..=1.0 + 1e-12).contains(&x)));
    });
}

/// DP vs SP: same in-core cycle cost per CL for SIMD variants (the paper's
/// Sect. 4 observation), exactly half the updates.
#[test]
fn dp_sp_relationship() {
    property("DP = SP cycles, half work", 40, |g| {
        let machines = all_machines();
        let m = g.choose(&machines);
        let v = *g.choose(&[Variant::KahanSimd, Variant::KahanSimdFma, Variant::NaiveSimd]);
        let sp = ecm::derive::paper_row(m, v, Precision::Sp, MemLevel::Mem);
        let dp = ecm::derive::paper_row(m, v, Precision::Dp, MemLevel::Mem);
        assert_eq!(sp.updates_per_cl, 2 * dp.updates_per_cl);
        assert!((sp.t_ol - dp.t_ol).abs() < 1e-9, "{} vs {}", sp.t_ol, dp.t_ol);
    });
}

/// Backend parity: every rung of the native Kahan-dot ladder matches the
/// exact ground truth of `accuracy/exact.rs` within the paper's compensated
/// error bound, across `generator.rs` conditionings. The bound combines the
/// Kahan summation term (2·eps·Σ|x·y|, n-independent) with the uncompensated
/// product roundings (≤ eps·Σ|x·y|); 8·eps·Σ leaves slack for the lane fold.
#[test]
fn native_kahan_ladder_matches_exact_within_bound() {
    let backend = NativeBackend::new();
    property("native kahan within paper bound", 30, |g| {
        let n = g.usize(2, 300) * 2 + 4; // even, >= 8
        let ce = g.f64_range(2.0, 30.0);
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let (x, y, exact) = ill_conditioned_dot(n, 2f64.powf(ce), &mut rng);
        let cond_sum: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let input = KernelInput::Dot(&x, &y);
        for spec in backend.kernels() {
            if spec.class != KernelClass::KahanDot {
                continue;
            }
            let got = backend.run(spec, &input).unwrap();
            assert!(
                (got - exact).abs() <= 8.0 * f64::EPSILON * cond_sum,
                "{spec}: err {} > bound {} (n = {n}, cond 2^{ce:.1})",
                (got - exact).abs(),
                8.0 * f64::EPSILON * cond_sum
            );
        }
    });
}

/// Naive-vs-Kahan error ordering holds across generator conditionings for
/// the native backend: Kahan wins the clear majority of cases and the
/// aggregate (geomean) error ratio is decisive — as in the accuracy-zoo
/// tests, per-case ties happen on benign draws.
#[test]
fn native_error_ordering_across_conditionings() {
    let backend = NativeBackend::new();
    let naive = KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes);
    let kahan = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
    let mut rng = Rng::new(2016);
    let mut kahan_wins = 0;
    let mut trials = 0;
    let mut ratios = Vec::new();
    for &ce in &[12, 24, 36, 48] {
        for _ in 0..5 {
            let (x, y, exact) = ill_conditioned_dot(512, 2f64.powi(ce), &mut rng);
            let input = KernelInput::Dot(&x, &y);
            let e_naive = (backend.run(naive, &input).unwrap() - exact).abs();
            let e_kahan = (backend.run(kahan, &input).unwrap() - exact).abs();
            trials += 1;
            if e_kahan <= e_naive {
                kahan_wins += 1;
            }
            ratios.push((e_naive + 1e-300) / (e_kahan + 1e-300));
        }
    }
    assert!(
        kahan_wins >= trials / 2 + 2,
        "kahan won only {kahan_wins}/{trials}"
    );
    let g = kahan_ecm::util::stats::geomean(&ratios);
    assert!(g >= 4.0, "naive/kahan error geomean ratio only {g}");
}

/// Acceptance pin: when the compensated accumulation is actually exercised
/// in its guaranteed regime — exactly representable products (y = 1) and no
/// catastrophic cancellation (positive summands, so Σ|x·y| = |result|) —
/// every native Kahan-dot rung agrees with the exact reference to <= 2 ulp
/// on accuracy-study generator magnitudes. (With rounded products Kahan
/// cannot beat eps·Σ|x·y| — product roundings are uncompensated, which is
/// dot2's job — so that regime is pinned by the compensated bound above.)
#[test]
fn native_kahan_two_ulp_on_benign_inputs() {
    let backend = NativeBackend::new();
    let mut rng = Rng::new(0xACC);
    for trial in 0..10 {
        let (raw, _, _) = ill_conditioned_dot(2048, 2f64.powi(2), &mut rng);
        let x: Vec<f64> = raw.iter().map(|v| v.abs()).collect();
        let y = vec![1.0; x.len()];
        let exact = kahan_ecm::accuracy::exact::exact_dot(&x, &y);
        let ulp = exact.abs() * f64::EPSILON;
        let input = KernelInput::Dot(&x, &y);
        for spec in backend.kernels() {
            if spec.class != KernelClass::KahanDot {
                continue;
            }
            let got = backend.run(spec, &input).unwrap();
            assert!(
                (got - exact).abs() <= 2.0 * ulp,
                "{spec} trial {trial}: {got} vs exact {exact} ({} ulp)",
                (got - exact).abs() / ulp.max(f64::MIN_POSITIVE)
            );
        }
    }
}

/// Thread-parallel execution is deterministic at a fixed thread count: the
/// partition depends only on (n, T) and the compensated tree combines
/// partials in partition order, so repeated runs are bit-identical — and
/// T = 1 is bit-identical to the serial backend.
#[test]
fn parallel_kahan_deterministic_at_fixed_threads() {
    let serial = NativeBackend::new();
    property("parallel deterministic, T=1 == serial", 20, |g| {
        let n = g.usize(0, 3000);
        let x = g.vec_f64_log(n, -12, 12);
        let y = g.vec_f64_log(n, -12, 12);
        let input = KernelInput::Dot(&x, &y);
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        for threads in [1usize, 2, 3, 8] {
            let par = ParallelBackend::new(threads);
            let a = par.run(spec, &input).unwrap();
            let b = par.run(spec, &input).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "T={threads} n={n}");
        }
        let s = serial.run(spec, &input).unwrap();
        let p1 = ParallelBackend::new(1).run(spec, &input).unwrap();
        assert_eq!(s.to_bits(), p1.to_bits(), "n={n}");
    });
}

/// The parallel Kahan dot stays within the serial compensated error bound
/// for any thread count: each worker carries its own compensation over its
/// slice and the tree reduction only adds exactly-tracked two_sum residues,
/// so the n-independent 8·eps·Σ|x·y| bound survives the partitioning.
#[test]
fn parallel_kahan_within_compensated_bound() {
    property("parallel kahan within paper bound", 25, |g| {
        let n = g.usize(4, 400) * 2 + 4;
        let ce = g.f64_range(2.0, 30.0);
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let (x, y, exact) = ill_conditioned_dot(n, 2f64.powf(ce), &mut rng);
        let cond_sum: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let input = KernelInput::Dot(&x, &y);
        for threads in [1usize, 2, 3, 8] {
            let par = ParallelBackend::new(threads);
            for style in [ImplStyle::Scalar, ImplStyle::SimdLanes] {
                let spec = KernelSpec::new(KernelClass::KahanDot, style);
                let got = par.run(spec, &input).unwrap();
                assert!(
                    (got - exact).abs() <= 8.0 * f64::EPSILON * cond_sum,
                    "{spec} T={threads}: err {} > bound {} (n = {n}, cond 2^{ce:.1})",
                    (got - exact).abs(),
                    8.0 * f64::EPSILON * cond_sum
                );
            }
        }
    });
}

/// The naive-vs-Kahan error ordering of the serial backends survives
/// threading: aggregated over ill-conditioned draws, the threaded Kahan dot
/// stays clearly more accurate than the threaded naive dot. The margin is
/// thinner than in the serial test (geomean ~2.5 vs ~4+, validated against
/// a bit-exact replica): chunking *helps* the naive kernel, because the
/// cross-chunk combination goes through the compensated tree even for naive
/// partials — only within-chunk roundings remain uncompensated.
#[test]
fn parallel_error_ordering_still_holds() {
    let naive = KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes);
    let kahan = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
    let mut rng = Rng::new(2024);
    for threads in [2usize, 3, 8] {
        let par = ParallelBackend::new(threads);
        let mut kahan_wins = 0;
        let mut trials = 0;
        let mut ratios = Vec::new();
        for &ce in &[12, 24, 36, 48] {
            for _ in 0..5 {
                let (x, y, exact) = ill_conditioned_dot(512, 2f64.powi(ce), &mut rng);
                let input = KernelInput::Dot(&x, &y);
                let e_naive = (par.run(naive, &input).unwrap() - exact).abs();
                let e_kahan = (par.run(kahan, &input).unwrap() - exact).abs();
                trials += 1;
                if e_kahan <= e_naive {
                    kahan_wins += 1;
                }
                ratios.push((e_naive + 1e-300) / (e_kahan + 1e-300));
            }
        }
        assert!(
            kahan_wins >= trials / 2 + 1,
            "T={threads}: kahan won only {kahan_wins}/{trials}"
        );
        let g = kahan_ecm::util::stats::geomean(&ratios);
        assert!(g >= 1.8, "T={threads}: error geomean ratio only {g}");
    }
}

/// The compensated tree reduction is exact whenever the true sum of the
/// partials is representable: recovered roundings ride the residue channel.
#[test]
fn tree_reduce_recovers_representable_sums() {
    property("tree reduce exact on representable sums", 60, |g| {
        // Integers scaled by a power of two: all intermediate two_sum
        // residues and the final sum are representable, so the reduction
        // must be exact regardless of magnitude spread.
        let t = g.usize(1, 24);
        let scale = 2f64.powi(g.u64(0, 40) as i32);
        let parts: Vec<f64> = (0..t)
            .map(|_| (g.u64(0, 1 << 20) as f64 - (1 << 19) as f64) * scale)
            .collect();
        let want: f64 = parts.iter().sum::<f64>(); // exact: all same scale, 20-bit ints
        let got = compensated_tree_reduce(&parts);
        assert_eq!(got, want, "{parts:?}");
        // And the partition machinery it rides on covers the index space.
        let pool = ThreadPool::new(t);
        let n = g.usize(0, 5000);
        let ranges = pool.partition(n, 8);
        let covered: usize = ranges.iter().map(|r| r.end - r.start).sum();
        assert_eq!(covered, n);
    });
}

/// Every explicit-SIMD rung (AVX2 single- and multi-accumulator, AVX-512
/// when compiled in; the portable fallback otherwise) is bit-identical to
/// its `mul_add`-based portable reference, on 64-byte-aligned arena
/// operands (the aligned-load fast path), on deliberately misaligned views
/// (`&buf[1..]`, an 8-byte offset that defeats both 32- and 64-byte
/// alignment), and across every remainder class n mod 32 ∈ {0..31} — the
/// dedicated-scalar-tail contract documented next to `fold_kahan_lanes`.
#[test]
fn explicit_simd_rungs_bit_match_reference_on_all_remainders() {
    type Dot = fn(&[f64], &[f64]) -> f64;
    type Sum = fn(&[f64]) -> f64;
    let dot_pairs: [(Dot, Dot); 12] = [
        (native::naive_dot_avx2, native::naive_dot_fma_ref::<4, 1>),
        (native::naive_dot_avx2_u2, native::naive_dot_fma_ref::<4, 2>),
        (native::naive_dot_avx2_u4, native::naive_dot_fma_ref::<4, 4>),
        (native::naive_dot_avx2_u8, native::naive_dot_fma_ref::<4, 8>),
        (native::kahan_dot_avx2, native::kahan_dot_fma_ref::<4, 1>),
        (native::kahan_dot_avx2_u2, native::kahan_dot_fma_ref::<4, 2>),
        (native::kahan_dot_avx2_u4, native::kahan_dot_fma_ref::<4, 4>),
        (native::kahan_dot_avx2_u8, native::kahan_dot_fma_ref::<4, 8>),
        (native::naive_dot_avx512, native::naive_dot_fma_ref::<8, 1>),
        (native::naive_dot_avx512_u8, native::naive_dot_fma_ref::<8, 8>),
        (native::kahan_dot_avx512_u4, native::kahan_dot_fma_ref::<8, 4>),
        (native::kahan_dot_avx512_u8, native::kahan_dot_fma_ref::<8, 8>),
    ];
    let sum_pairs: [(Sum, Sum); 6] = [
        (native::kahan_sum_avx2, native::kahan_sum_wide_ref::<4, 1>),
        (native::kahan_sum_avx2_u2, native::kahan_sum_wide_ref::<4, 2>),
        (native::kahan_sum_avx2_u4, native::kahan_sum_wide_ref::<4, 4>),
        (native::kahan_sum_avx2_u8, native::kahan_sum_wide_ref::<4, 8>),
        (native::kahan_sum_avx512, native::kahan_sum_wide_ref::<8, 1>),
        (native::kahan_sum_avx512_u8, native::kahan_sum_wide_ref::<8, 8>),
    ];
    let mut rng = Rng::new(0xA11);
    let cap = 256 + 33;
    let xbuf = AlignedVec::from_fn(cap, |_| rng.normal());
    let ybuf = AlignedVec::from_fn(cap, |_| rng.normal());
    assert_eq!(xbuf.as_ptr() as usize % ALIGN, 0);
    for r in 0..32usize {
        // One short length (tail-only for the wide rungs) and one that
        // exercises full vector blocks, both in remainder class r.
        for n in [r, 224 + r] {
            let aligned = (&xbuf[..n], &ybuf[..n]);
            let shifted = (&xbuf[1..n + 1], &ybuf[1..n + 1]);
            for (i, &(f, reference)) in dot_pairs.iter().enumerate() {
                for (x, y) in [aligned, shifted] {
                    assert_eq!(
                        f(x, y).to_bits(),
                        reference(x, y).to_bits(),
                        "dot pair #{i}, n = {n}"
                    );
                }
            }
            for (i, &(f, reference)) in sum_pairs.iter().enumerate() {
                for x in [aligned.0, shifted.0] {
                    assert_eq!(
                        f(x).to_bits(),
                        reference(x).to_bits(),
                        "sum pair #{i}, n = {n}"
                    );
                }
            }
        }
    }
}

/// Arena invariants: every allocation is 64-byte aligned, and the
/// first-touch parallel copy is bit-identical to its source for any worker
/// count (placement changes, values never do).
#[test]
fn arena_alignment_and_first_touch_parity() {
    property("arena first-touch parity", 25, |g| {
        let n = g.usize(0, 4000);
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let src: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let threads = g.usize(1, 8);
        let backend = ParallelBackend::new(threads);
        let v = AlignedVec::first_touch_copy(&src, backend.pool());
        assert_eq!(v.as_ptr() as usize % ALIGN, 0, "n={n} T={threads}");
        assert_eq!(v.len(), n);
        for (a, b) in v.iter().zip(&src) {
            assert_eq!(a.to_bits(), b.to_bits(), "n={n} T={threads}");
        }
        // The serial constructors obey the same alignment invariant.
        let w = AlignedVec::copy_from(&src);
        assert_eq!(w.as_ptr() as usize % ALIGN, 0);
        assert_eq!(&w[..], &src[..]);
    });
}

/// The persistent pool preserves the spawn-per-dispatch semantics
/// bit-for-bit: one backend instance re-dispatching the same input (pool
/// reuse — the `bench-scale` hot path) returns identical bits every time,
/// and a freshly spawned pool of the same width agrees, because the result
/// depends only on the partition, never on which OS thread ran a chunk.
#[test]
fn persistent_pool_reuse_matches_fresh_pool_bitwise() {
    let mut rng = Rng::new(0x9001);
    let x: Vec<f64> = (0..8200).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..8200).map(|_| rng.normal()).collect();
    let input = KernelInput::Dot(&x, &y);
    for threads in [2usize, 3, 6] {
        let backend = ParallelBackend::new(threads);
        for style in [ImplStyle::Scalar, ImplStyle::SimdLanes, ImplStyle::Unroll8] {
            let spec = KernelSpec::new(KernelClass::KahanDot, style);
            let first = backend.run(spec, &input).unwrap();
            for rep in 0..8 {
                let again = backend.run(spec, &input).unwrap();
                assert_eq!(
                    first.to_bits(),
                    again.to_bits(),
                    "{spec} T={threads} rep={rep}"
                );
            }
            let fresh = ParallelBackend::new(threads).run(spec, &input).unwrap();
            assert_eq!(first.to_bits(), fresh.to_bits(), "{spec} T={threads} fresh");
        }
        // Pool-level reuse with a plain closure stays shape-stable too.
        let pool = backend.pool();
        let sizes = pool.run_chunks(x.len(), CACHELINE_F64, |_, r| r.len());
        assert_eq!(sizes.iter().sum::<usize>(), x.len());
    }
}

/// The portable-SIMD layouts are bit-identical to their 4-chain unrolled
/// counterparts for arbitrary lengths (including ragged tails) — the lane
/// code is a re-expression, not a renumbering, of the unrolled recurrence.
#[test]
fn native_simd_bitwise_equals_unroll4() {
    property("simd == unroll4 bitwise", 40, |g| {
        let n = g.usize(0, 200);
        let x = g.vec_f64_log(n, -20, 20);
        let y = g.vec_f64_log(n, -20, 20);
        assert_eq!(
            native::naive_dot_simd(&x, &y).to_bits(),
            native::naive_dot_unrolled::<4>(&x, &y).to_bits()
        );
        assert_eq!(
            native::kahan_dot_simd(&x, &y).to_bits(),
            native::kahan_dot_unrolled::<4>(&x, &y).to_bits()
        );
        assert_eq!(
            native::kahan_sum_simd(&x).to_bits(),
            native::kahan_sum_unrolled::<4>(&x).to_bits()
        );
    });
}

/// Mov elimination: adding redundant movs to a body never changes the OoO
/// steady state (they are renamed away).
#[test]
fn movs_are_free_on_ooo() {
    let m = haswell();
    property("renamed movs are free", 20, |g| {
        let unroll = g.u64(2, 6) as u32;
        let k = build(Variant::KahanSimd, 8, unroll, Precision::Sp, &[]);
        let base = simulate_core(&m, &k, 1).cycles_per_body;
        let mut k2 = k.clone();
        // Duplicate the trailing movs.
        let movs: Vec<_> = k2
            .body
            .iter()
            .filter(|i| i.op == OpClass::Mov)
            .cloned()
            .collect();
        k2.body.extend(movs);
        let with = simulate_core(&m, &k2, 1).cycles_per_body;
        assert!(
            (with - base).abs() < 0.51,
            "movs changed II: {base} -> {with}"
        );
    });
}

/// The serving layer's bit-parity contract: a request returns bit-identical
/// results whether submitted alone, inside a random batch, or in a repeated
/// dispatch — at a fixed thread count the scheduler may move work between
/// workers but never change what a request computes.
#[test]
fn serving_batched_equals_unbatched_bits() {
    property("serve batched == unbatched bitwise", 10, |g| {
        let threads = *g.choose(&[1usize, 2, 3]);
        let threshold = g.usize(32, 2048);
        let service = DotService::new(ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated: g.bool(),
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        })
        .unwrap();
        let k = g.usize(1, 8);
        let data: Vec<(Vec<f64>, Vec<f64>)> = (0..k)
            .map(|_| {
                // Cluster sizes around the threshold so both paths occur.
                let n = g.usize(0, 2 * threshold + 64);
                (g.vec_f64_log(n, -20, 20), g.vec_f64_log(n, -20, 20))
            })
            .collect();
        let inputs: Vec<KernelInput<'_>> = data
            .iter()
            .map(|(x, y)| {
                if x.len() % 3 == 0 {
                    KernelInput::Sum(x)
                } else {
                    KernelInput::Dot(x, y)
                }
            })
            .collect();
        let batched = service.submit_batch(&inputs).unwrap();
        let again = service.submit_batch(&inputs).unwrap();
        for ((input, b), b2) in inputs.iter().zip(&batched).zip(&again) {
            let alone = service.submit(input).unwrap();
            assert_eq!(
                alone.value.to_bits(),
                b.value.to_bits(),
                "n={} T={threads} threshold={threshold}",
                b.n
            );
            assert_eq!(b.value.to_bits(), b2.value.to_bits(), "redispatch n={}", b.n);
            assert_eq!(alone.path, b.path);
        }
    });
}

/// A sharded request is the measurement path: bit-identical to the
/// thread-parallel backend at the same T (same rung, same cache-line
/// partition, same compensated tree reduction).
#[test]
fn serving_sharded_matches_parallel_backend_bits() {
    property("serve sharded == ParallelBackend bitwise", 10, |g| {
        let threads = *g.choose(&[2usize, 3, 8]);
        let n = g.usize(64, 6000);
        let x = g.vec_f64_log(n, -20, 20);
        let y = g.vec_f64_log(n, -20, 20);
        let compensated = g.bool();
        let service = DotService::new(ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated,
            shard_threshold: ThresholdMode::Fixed(0), // shard everything
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        })
        .unwrap();
        let backend = ParallelBackend::new(threads);
        let input = KernelInput::Dot(&x, &y);
        let served = service.submit(&input).unwrap();
        assert_eq!(served.path, ExecPath::Sharded);
        let reference = backend.run(service.dot_spec(), &input).unwrap();
        assert_eq!(served.value.to_bits(), reference.to_bits(), "T={threads} n={n}");
        let s_input = KernelInput::Sum(&x);
        let served = service.submit(&s_input).unwrap();
        let reference = backend.run(service.sum_spec(), &s_input).unwrap();
        assert_eq!(served.value.to_bits(), reference.to_bits(), "sum T={threads} n={n}");
    });
}

/// The crossover threshold is respected exactly at its boundary, for any
/// threshold: n = threshold - 1 fuses, n = threshold shards.
#[test]
fn serving_crossover_boundary_exact() {
    property("serve crossover boundary", 12, |g| {
        let threshold = g.usize(16, 4096);
        let service = DotService::new(ServeConfig {
            threads: 2,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        })
        .unwrap();
        let x = g.vec_f64_log(threshold, -10, 10);
        let y = g.vec_f64_log(threshold, -10, 10);
        let below = service
            .submit(&KernelInput::Dot(&x[..threshold - 1], &y[..threshold - 1]))
            .unwrap();
        assert_eq!(below.path, ExecPath::Fused, "threshold={threshold}");
        let at = service.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(at.path, ExecPath::Sharded, "threshold={threshold}");
        let stats = service.stats();
        assert_eq!((stats.fused, stats.sharded), (1, 1));
    });
}

/// Serving is deterministic across *fresh* services of the same shape —
/// the batch results depend on (rung, T, threshold, operands) only, never
/// on pool identity or scheduling history.
#[test]
fn serving_deterministic_across_fresh_services() {
    let mut rng = Rng::new(77);
    let data: Vec<(Vec<f64>, Vec<f64>)> = [100usize, 900, 2000, 33]
        .iter()
        .map(|&n| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    let inputs: Vec<KernelInput<'_>> = data.iter().map(|(x, y)| KernelInput::Dot(x, y)).collect();
    let cfg = || ServeConfig {
        threads: 3,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(512),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let a = DotService::new(cfg()).unwrap().submit_batch(&inputs).unwrap();
    let b = DotService::new(cfg()).unwrap().submit_batch(&inputs).unwrap();
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.value.to_bits(), rb.value.to_bits(), "n={}", ra.n);
        assert_eq!(ra.path, rb.path);
    }
}

/// An `Arc`'d aligned copy, the form the operand store consumes.
fn arc_operand(v: &[f64]) -> std::sync::Arc<AlignedVec> {
    std::sync::Arc::new(AlignedVec::copy_from(v))
}

fn serve_cfg(threads: usize, threshold: usize) -> ServeConfig {
    ServeConfig {
        threads,
        style: ImplStyle::SimdLanes,
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(threshold),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    }
}

/// The tentpole determinism contract: results submitted through the async
/// pipeline are bit-identical to the synchronous `submit_batch` at a fixed
/// thread count, for mixed fused/sharded (dot and sum) workloads, under at
/// least two arrival interleavings — all-at-once (the dispatcher drains
/// arbitrary arrival batches) and strictly one-at-a-time with a zero
/// batching window (every request its own batch). Only completion order
/// may vary; values may not.
#[test]
fn async_serving_bit_matches_sync_under_two_interleavings() {
    let mut rng = Rng::new(0xA57);
    let threshold = 2048usize;
    let data: Vec<(Vec<f64>, Vec<f64>)> = [17usize, 600, 2047, 2048, 2049, 7000, 64]
        .iter()
        .map(|&n| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    let inputs: Vec<KernelInput<'_>> = data
        .iter()
        .enumerate()
        .map(|(i, (x, y))| {
            if i % 3 == 0 {
                KernelInput::Sum(x)
            } else {
                KernelInput::Dot(x, y)
            }
        })
        .collect();
    let shared: Vec<SharedInput> = data
        .iter()
        .enumerate()
        .map(|(i, (x, y))| {
            if i % 3 == 0 {
                SharedInput::sum(x)
            } else {
                SharedInput::dot(x, y)
            }
        })
        .collect();
    for threads in [1usize, 2, 3] {
        let sync = DotService::new(serve_cfg(threads, threshold)).unwrap();
        let want = sync.submit_batch(&inputs).unwrap();
        // Interleaving 1: submit everything, then wait in submission
        // order (arrival batches form however the dispatcher drains).
        let burst =
            AsyncDotService::new(serve_cfg(threads, threshold), AsyncOptions::default()).unwrap();
        let got = burst.submit_wait(&shared).unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.value.to_bits(), g.value.to_bits(), "burst n={} T={threads}", w.n);
            assert_eq!(w.path, g.path);
        }
        // Interleaving 2: one request at a time, each waited before the
        // next is submitted, through a zero-window pipeline (every
        // request is its own arrival batch).
        let single = AsyncDotService::new(
            serve_cfg(threads, threshold),
            AsyncOptions {
                batch_window: std::time::Duration::ZERO,
                batch_max: 1,
                ..AsyncOptions::default()
            },
        )
        .unwrap();
        for (w, input) in want.iter().zip(&shared) {
            let g = single.submit(input.clone()).unwrap().wait().unwrap();
            assert_eq!(w.value.to_bits(), g.value.to_bits(), "single n={} T={threads}", w.n);
            assert_eq!(w.path, g.path);
        }
    }
}

/// The backpressure bound is real: submitting far more requests than the
/// queue depth never grows the queue past the depth (submit blocks
/// instead), and everything still completes exactly once.
#[test]
fn async_bounded_queue_depth_bounds_memory() {
    let depth = 4usize;
    let asy = AsyncDotService::new(
        serve_cfg(2, usize::MAX),
        AsyncOptions {
            queue_depth: depth,
            ..AsyncOptions::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(0xBACC);
    let x: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
    let input = SharedInput::dot(&x, &y);
    let total = depth * 16;
    let handles: Vec<_> = (0..total)
        .map(|_| asy.submit(input.clone()).unwrap())
        .collect();
    let want = asy.service().submit(&input.view()).unwrap();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.value.to_bits(), want.value.to_bits());
    }
    let stats = asy.stats();
    assert_eq!(stats.enqueued, total as u64);
    assert_eq!(stats.completed, total as u64);
    assert!(
        stats.max_queue_depth <= depth,
        "queue grew past its depth: {} > {depth}",
        stats.max_queue_depth
    );
}

/// Ticket life cycle: `try_wait` polls without consuming, `wait` resolves
/// exactly once with the same bits, and dropping handles without waiting
/// neither blocks shutdown nor loses the requests (they complete and are
/// counted).
#[test]
fn async_tickets_poll_resolve_once_and_survive_unwaited_drops() {
    let mut rng = Rng::new(0x71C7);
    let x: Vec<f64> = (0..1500).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..1500).map(|_| rng.normal()).collect();
    let input = SharedInput::dot(&x, &y);
    let asy = AsyncDotService::new(serve_cfg(2, 512), AsyncOptions::default()).unwrap();
    let want = asy.service().submit(&input.view()).unwrap();
    let handle = asy.submit(input.clone()).unwrap();
    let peeked = loop {
        if let Some(r) = handle.try_wait() {
            break r.unwrap();
        }
        std::thread::yield_now();
    };
    assert_eq!(peeked.value.to_bits(), want.value.to_bits());
    let waited = handle.wait().unwrap();
    assert_eq!(waited.value.to_bits(), want.value.to_bits());
    // Fire-and-forget: handles dropped immediately, requests still served.
    for _ in 0..12 {
        drop(asy.submit(input.clone()).unwrap());
    }
    drop(asy); // drains in-flight work and joins the dispatcher
}

/// Shutdown drains: requests accepted before the service is dropped are
/// executed, their tickets resolve afterwards, and late submits fail
/// cleanly instead of hanging.
#[test]
fn async_shutdown_drains_accepted_work() {
    let mut rng = Rng::new(0xD0D0);
    let sync = DotService::new(serve_cfg(2, 1024)).unwrap();
    let asy = AsyncDotService::new(serve_cfg(2, 1024), AsyncOptions::default()).unwrap();
    let mut expected = Vec::new();
    let mut handles = Vec::new();
    for i in 0..16 {
        let n = 200 + (i % 4) * 700;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        expected.push(sync.submit(&KernelInput::Dot(&x, &y)).unwrap());
        handles.push(asy.submit(SharedInput::dot(&x, &y)).unwrap());
    }
    drop(asy);
    for (want, h) in expected.iter().zip(handles) {
        let got = h.wait().expect("accepted requests must drain on shutdown");
        assert_eq!(got.value.to_bits(), want.value.to_bits());
        assert_eq!(got.path, want.path);
    }
}

/// Wire-codec round trips are bit-exact for every opcode: any frame built
/// by an encoder decodes back to the same request or response, with every
/// `f64` compared as its raw IEEE-754 bit pattern — the codec never
/// parses, formats, or rounds a value (PROTOCOL.md §1, §3.1).
#[test]
fn wire_codec_round_trips_bit_exact() {
    use kahan_ecm::serve::codec::{
        self, ErrorCode, Opcode, Request, Response, WireResult, WireStats, HEADER_LEN,
    };

    fn split(frame: &[u8]) -> (Opcode, u64, Vec<u8>) {
        let head: &[u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
        let h = codec::decode_header(head).unwrap();
        let payload = frame[HEADER_LEN..].to_vec();
        assert_eq!(payload.len(), h.payload_len as usize);
        (Opcode::from_byte(h.opcode).unwrap(), h.request_id, payload)
    }
    fn assert_same_input(a: &SharedInput, b: &SharedInput) {
        match (a.view(), b.view()) {
            (KernelInput::Dot(ax, ay), KernelInput::Dot(bx, by)) => {
                assert_eq!((ax.len(), ay.len()), (bx.len(), by.len()));
                for (p, q) in ax.iter().zip(bx).chain(ay.iter().zip(by)) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
            }
            (KernelInput::Sum(ax), KernelInput::Sum(bx)) => {
                assert_eq!(ax.len(), bx.len());
                for (p, q) in ax.iter().zip(bx) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
            }
            _ => panic!("request kind changed across the wire"),
        }
    }

    property("codec round trips bit-exact", 40, |g| {
        let id = g.u64(0, u64::MAX - 1);
        let n = g.usize(0, 300);
        let x = g.vec_f64_log(n, -30, 30);
        let y = g.vec_f64_log(n, -30, 30);

        // Inline dot and sum requests (PROTOCOL.md §3.1–3.2).
        let frame = codec::encode_dot(id, &x, &y);
        assert_eq!(frame.len(), HEADER_LEN + codec::dot_payload_len(n));
        let (op, rid, payload) = split(&frame);
        assert_eq!(rid, id);
        match codec::decode_request(op, &payload).unwrap() {
            Request::Submit(input) => assert_same_input(&input, &SharedInput::dot(&x, &y)),
            other => panic!("expected a dot submit, got {other:?}"),
        }
        let frame = codec::encode_sum(id, &x);
        assert_eq!(frame.len(), HEADER_LEN + codec::sum_payload_len(n));
        let (op, _, payload) = split(&frame);
        match codec::decode_request(op, &payload).unwrap() {
            Request::Submit(input) => assert_same_input(&input, &SharedInput::sum(&x)),
            other => panic!("expected a sum submit, got {other:?}"),
        }

        // A mixed batch (PROTOCOL.md §3.3) keeps kinds, order, and bits.
        let count = g.usize(1, 4);
        let inputs: Vec<SharedInput> = (0..count)
            .map(|i| {
                if (i + n) % 2 == 0 {
                    SharedInput::sum(&x)
                } else {
                    SharedInput::dot(&x, &y)
                }
            })
            .collect();
        let (op, _, payload) = split(&codec::encode_batch(id, &inputs));
        match codec::decode_request(op, &payload).unwrap() {
            Request::Batch(decoded) => {
                assert_eq!(decoded.len(), inputs.len());
                for (d, i) in decoded.iter().zip(&inputs) {
                    assert_same_input(d, i);
                }
            }
            other => panic!("expected a batch, got {other:?}"),
        }

        // Stats probe (PROTOCOL.md §3.4) — empty payload.
        let (op, _, payload) = split(&codec::encode_stats(id));
        assert!(payload.is_empty());
        assert!(matches!(codec::decode_request(op, &payload).unwrap(), Request::Stats));

        // Scalar result (PROTOCOL.md §3.5), including negative zero and
        // whatever magnitudes the generator produced.
        let result = WireResult {
            value: if n > 0 { x[0] } else { -0.0 },
            n: n as u64,
            path: if g.bool() { ExecPath::Fused } else { ExecPath::Sharded },
            err_bound: None,
        };
        let (op, rid, payload) = split(&codec::encode_result(id, &result));
        assert_eq!(rid, id);
        match codec::decode_response(op, &payload).unwrap() {
            Response::Result(r) => {
                assert_eq!(r.value.to_bits(), result.value.to_bits());
                assert_eq!((r.n, r.path), (result.n, result.path));
            }
            other => panic!("expected a result, got {other:?}"),
        }

        // Scalar result with the revision-1.4 FLAG_ERRBOUND extension
        // (PROTOCOL.md §3.5): the certified bound survives bit-exactly and
        // the flag is set on the wire.
        let bounded = WireResult {
            err_bound: Some(g.f64_range(0.0, 1e-6)),
            ..result
        };
        let bframe = codec::encode_result(id, &bounded);
        assert_ne!(bframe[6] & 0x20, 0, "FLAG_ERRBOUND must be set");
        let flags = bframe[6];
        let (op, rid, payload) = split(&bframe);
        assert_eq!(rid, id);
        match codec::decode_response_flagged(flags, op, &payload).unwrap() {
            Response::Result(r) => {
                assert_eq!(r.value.to_bits(), bounded.value.to_bits());
                assert_eq!(
                    r.err_bound.map(f64::to_bits),
                    bounded.err_bound.map(f64::to_bits)
                );
            }
            other => panic!("expected a bounded result, got {other:?}"),
        }

        // Batch result (PROTOCOL.md §3.6) in submission order.
        let results: Vec<WireResult> = (0..count)
            .map(|i| WireResult {
                value: if n > 0 { x[i % n.max(1)] } else { 0.0 },
                n: i as u64,
                path: if i % 2 == 0 { ExecPath::Fused } else { ExecPath::Sharded },
                err_bound: None,
            })
            .collect();
        let (op, _, payload) = split(&codec::encode_batch_result(id, &results));
        match codec::decode_response(op, &payload).unwrap() {
            Response::Batch(decoded) => {
                assert_eq!(decoded.len(), results.len());
                for (d, r) in decoded.iter().zip(&results) {
                    assert_eq!(d.value.to_bits(), r.value.to_bits());
                    assert_eq!((d.n, d.path), (r.n, r.path));
                }
            }
            other => panic!("expected a batch result, got {other:?}"),
        }

        // Stats snapshot (PROTOCOL.md §3.7): eight u64s survive verbatim.
        let stats = WireStats {
            queue_depth: g.u64(0, 1 << 20),
            threads: g.u64(1, 256),
            enqueued: g.u64(0, u64::MAX - 1),
            completed: g.u64(0, u64::MAX - 1),
            arrival_batches: g.u64(0, 1 << 40),
            dispatches: g.u64(0, 1 << 40),
            max_queue_depth: g.u64(0, 1 << 20),
            busy_ns: g.u64(0, u64::MAX - 1),
        };
        let (op, _, payload) = split(&codec::encode_stats_result(id, &stats));
        match codec::decode_response(op, &payload).unwrap() {
            Response::Stats(s) => assert_eq!(s, stats),
            other => panic!("expected stats, got {other:?}"),
        }

        // Typed error frame (PROTOCOL.md §4): every code round-trips.
        let code = *g.choose(&[
            ErrorCode::BadMagic,
            ErrorCode::BadVersion,
            ErrorCode::BadOpcode,
            ErrorCode::Malformed,
            ErrorCode::Oversized,
            ErrorCode::Invalid,
            ErrorCode::Busy,
            ErrorCode::Shutdown,
            ErrorCode::Internal,
            ErrorCode::Deadline,
            ErrorCode::Quota,
            ErrorCode::CorruptFrame,
            ErrorCode::CorruptOperand,
        ]);
        let (op, _, payload) = split(&codec::encode_error(id, code, "synthetic diagnostic"));
        match codec::decode_response(op, &payload).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, code);
                assert_eq!(e.message, "synthetic diagnostic");
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
    });
}

/// Hostile payloads never panic the codec: truncating a well-formed
/// request payload at *every* byte boundary yields a typed `Malformed`
/// error (the count prefix no longer matches the bytes), trailing garbage
/// is rejected by the exact-consumption rule (PROTOCOL.md §2.3), inflated
/// counts are caught by the element-capacity check before allocation, and
/// every header-level violation maps to its assigned error code.
#[test]
fn wire_codec_rejects_hostile_frames_without_panic() {
    use kahan_ecm::serve::codec::{self, ErrorCode, Opcode, HEADER_LEN, MAX_PAYLOAD, VERSION};

    property("codec rejects hostile frames", 25, |g| {
        let n = g.usize(1, 40);
        let x = g.vec_f64_log(n, -10, 10);
        let y = g.vec_f64_log(n, -10, 10);
        let requests: [(Opcode, Vec<u8>); 3] = [
            (Opcode::Dot, codec::encode_dot_payload(&x, &y)),
            (Opcode::Sum, codec::encode_sum_payload(&x)),
            (
                Opcode::Batch,
                codec::encode_batch(7, &[SharedInput::dot(&x, &y), SharedInput::sum(&x)])
                    [HEADER_LEN..]
                    .to_vec(),
            ),
        ];
        for (op, payload) in &requests {
            // The intact payload decodes...
            codec::decode_request(*op, payload).unwrap();
            // ...every truncation is a typed error, never a panic.
            for cut in 0..payload.len() {
                let err = codec::decode_request(*op, &payload[..cut]).unwrap_err();
                assert_eq!(err.code, ErrorCode::Malformed, "{op:?} cut at {cut}");
            }
            // Trailing garbage violates exact consumption (§2.3).
            let mut padded = payload.clone();
            padded.push(0xAA);
            assert_eq!(
                codec::decode_request(*op, &padded).unwrap_err().code,
                ErrorCode::Malformed
            );
        }

        // An inflated count prefix is rejected by the capacity check
        // before any allocation happens (§3.1).
        let mut lying = codec::encode_dot_payload(&x, &y);
        lying[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(
            codec::decode_request(Opcode::Dot, &lying).unwrap_err().code,
            ErrorCode::Malformed
        );

        // Header-level violations map to their assigned codes (§2.2, §4),
        // checked in the stream-trust order magic → version → cap →
        // flags/reserved.
        let good = codec::encode_stats(3);
        let head = |mutate: &dyn Fn(&mut [u8; HEADER_LEN])| {
            let mut h: [u8; HEADER_LEN] = good[..HEADER_LEN].try_into().unwrap();
            mutate(&mut h);
            codec::decode_header(&h)
        };
        assert_eq!(head(&|h| h[0] = b'X').unwrap_err().code, ErrorCode::BadMagic);
        assert_eq!(
            head(&|h| h[4] = VERSION + 1).unwrap_err().code,
            ErrorCode::BadVersion
        );
        let over = (MAX_PAYLOAD as u32) + 1;
        assert_eq!(
            head(&|h| h[16..20].copy_from_slice(&over.to_le_bytes()))
                .unwrap_err()
                .code,
            ErrorCode::Oversized
        );
        // The assigned flag bits are accepted (§2.4) — singly and
        // combined — while unknown bits and a non-zero reserved byte are
        // each non-fatal Malformed.
        for flag in [
            codec::FLAG_DEADLINE,
            codec::FLAG_TENANT,
            codec::FLAG_RETRY,
            codec::FLAG_CACHE,
            codec::FLAG_CRC,
            codec::FLAG_ERRBOUND,
            codec::FLAG_SCRUB,
        ] {
            assert_eq!(head(&|h| h[6] = flag).unwrap().flags, flag);
        }
        let both = codec::FLAG_DEADLINE | codec::FLAG_TENANT;
        assert_eq!(head(&|h| h[6] = both).unwrap().flags, both);
        let all = codec::FLAG_DEADLINE
            | codec::FLAG_TENANT
            | codec::FLAG_RETRY
            | codec::FLAG_CACHE
            | codec::FLAG_CRC
            | codec::FLAG_ERRBOUND
            | codec::FLAG_SCRUB;
        assert_eq!(head(&|h| h[6] = all).unwrap().flags, all);
        // 0x80 is the first genuinely unassigned bit in revision 1.4.
        assert_eq!(head(&|h| h[6] = 0x80).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(head(&|h| h[7] = 1).unwrap_err().code, ErrorCode::Malformed);
        // Magic outranks version: both wrong reports BadMagic first.
        assert_eq!(
            head(&|h| {
                h[0] = b'X';
                h[4] = VERSION + 1;
            })
            .unwrap_err()
            .code,
            ErrorCode::BadMagic
        );

        // Revision-1.4 CRC trailer (§2.6). An intact sealed frame
        // verifies, strips back to the original payload bytes, and still
        // decodes; the reference check value pins the polynomial.
        assert_eq!(codec::crc32c(b"123456789"), 0xE306_9283, "CRC32C check value");
        let plain = codec::encode_batch(9, &[SharedInput::dot(&x, &y), SharedInput::sum(&y)]);
        let mut sealed = plain.clone();
        codec::seal_crc(&mut sealed);
        assert_eq!(sealed.len(), plain.len() + codec::CRC_TRAILER_LEN);
        let shead: [u8; HEADER_LEN] = sealed[..HEADER_LEN].try_into().unwrap();
        let sflags = shead[6];
        assert_ne!(sflags & codec::FLAG_CRC, 0);
        let body = codec::verify_crc(&shead, sflags, &sealed[HEADER_LEN..]).unwrap();
        assert_eq!(body, &plain[HEADER_LEN..]);
        codec::decode_request(Opcode::Batch, body).unwrap();
        // Every single-bit flip in the sealed payload — body or trailer —
        // is the typed non-fatal CorruptFrame, never a panic or a wrong
        // decode.
        for i in HEADER_LEN..sealed.len() {
            let mut bent = sealed.clone();
            bent[i] ^= 1 << g.usize(0, 7);
            let err = codec::verify_crc(&shead, sflags, &bent[HEADER_LEN..]).unwrap_err();
            assert_eq!(err.code, ErrorCode::CorruptFrame, "flip at byte {i}");
        }
        // A flagged payload shorter than its own trailer is CorruptFrame
        // (length check), and losing the final byte is CorruptFrame
        // (checksum mismatch) — truncation never slips through.
        for cut in 0..codec::CRC_TRAILER_LEN {
            let err = codec::verify_crc(&shead, sflags, &sealed[HEADER_LEN..HEADER_LEN + cut])
                .unwrap_err();
            assert_eq!(err.code, ErrorCode::CorruptFrame, "trailer cut to {cut}");
        }
        let err = codec::verify_crc(&shead, sflags, &sealed[HEADER_LEN..sealed.len() - 1])
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::CorruptFrame);
        // Without the flag the verifier is a strict pass-through — the
        // revision-1.0 byte stream is untouched (CRC-off parity).
        let unflagged: [u8; HEADER_LEN] = plain[..HEADER_LEN].try_into().unwrap();
        assert_eq!(
            codec::verify_crc(&unflagged, unflagged[6], &plain[HEADER_LEN..]).unwrap(),
            &plain[HEADER_LEN..]
        );

        // A response opcode sent as a request (and vice versa) is a
        // BadOpcode at the decode layer (§3).
        assert_eq!(
            codec::decode_request(Opcode::Result, &[]).unwrap_err().code,
            ErrorCode::BadOpcode
        );
        assert_eq!(
            codec::decode_response(Opcode::Dot, &[]).unwrap_err().code,
            ErrorCode::BadOpcode
        );
    });
}

/// Resolve-exactly-once under injected faults, per in-process site —
/// including the tenant-facing sites: with a single fault armed at each
/// site in turn on a two-tenant weighted-fair service, every submitted
/// request resolves — a value, a typed error, or a typed admission shed,
/// never a hang — the injector's accounting confirms the fault actually
/// fired, and every successful result stays bit-identical to a clean
/// service at the same thread count (the degradation contract never buys
/// liveness with changed bits).
#[test]
fn fault_matrix_every_in_process_site_resolves_exactly_once() {
    use kahan_ecm::runtime::backend::BackendError;
    use kahan_ecm::serve::QosPolicy;
    use std::time::{Duration, Instant};
    let mut rng = Rng::new(0xFA117);
    let x: Vec<f64> = (0..1200).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..1200).map(|_| rng.normal()).collect();
    let input = SharedInput::dot(&x, &y);
    let clean = DotService::new(serve_cfg(2, 512)).unwrap();
    let want = clean.submit(&input.view()).unwrap();
    for &site in &FaultSite::IN_PROCESS {
        // Trigger 1 everywhere: the first arrival at a site always exists
        // (a 24-request burst may drain as a single arrival batch, so a
        // dispatcher-stall trigger beyond 1 would not be guaranteed, and
        // the starvation-stall site arms once per weighted-fair drain).
        let plan = if site.is_stall() {
            FaultPlan::none().with_stall(site, 1, Duration::from_millis(5))
        } else {
            FaultPlan::none().with(site, 1)
        };
        let injector = FaultInjector::new(plan);
        let policy = QosPolicy::parse("a:3,b:1").unwrap();
        let asy = AsyncDotService::new_with_qos(
            serve_cfg(2, 512),
            AsyncOptions::default(),
            Some(policy),
            Some(injector.clone()),
        )
        .unwrap();
        let total = 24usize;
        let mut shed = 0usize;
        let mut handles = Vec::new();
        for k in 0..total {
            match asy.submit_with_opts(input.clone(), Instant::now(), None, (k % 2) as u32, false) {
                Ok(h) => handles.push(h),
                Err(BackendError::QuotaExceeded { .. }) => shed += 1,
                Err(other) => panic!("{site:?}: unexpected submit error: {other}"),
            }
        }
        let (mut ok, mut errs) = (0usize, 0usize);
        for h in handles {
            match h.wait_timed_for(Duration::from_secs(30)) {
                Some(Ok((got, _))) => {
                    assert_eq!(got.value.to_bits(), want.value.to_bits(), "{site:?}");
                    assert_eq!(got.path, want.path, "{site:?}");
                    ok += 1;
                }
                Some(Err(_)) => errs += 1,
                None => panic!("{site:?}: request hung — resolve-exactly-once broken"),
            }
        }
        assert_eq!(ok + errs + shed, total, "{site:?}: every request must resolve");
        assert_eq!(injector.fired(site), 1, "{site:?}: armed fault must fire once");
        let quota_shed: u64 = asy.tenant_stats().iter().map(|r| r.quota_shed).sum();
        match site {
            FaultSite::WorkerPanic => {
                assert!(errs >= 1, "an injected panic must fail at least its own dispatch");
                assert!(ok >= 1, "the healed pool must serve the remaining requests");
            }
            FaultSite::QuotaAdmissionReject => {
                assert_eq!(shed, 1, "the armed admission check sheds exactly one request");
                assert_eq!(errs, 0, "a quota shed is an admission outcome, not a late error");
                assert_eq!(quota_shed, 1, "tenant accounting records the shed exactly once");
            }
            _ => assert_eq!(errs, 0, "{site:?}: stalls may only delay, never fail"),
        }
        if site != FaultSite::QuotaAdmissionReject {
            assert_eq!(shed, 0, "{site:?}: only the quota site sheds admissions");
            assert_eq!(quota_shed, 0, "{site:?}: no tenant may record a quota shed");
        }
    }
}

/// Worker self-healing preserves bit-parity at fixed T: an injected panic
/// kills one worker (failing only its own dispatch with the typed
/// worker-panic error), the pool respawns the slot before the next
/// dispatch, and every later result is bit-identical to a clean
/// synchronous service — the replacement worker inherits the slot index,
/// so the shard partition (and the reduction shape) is unchanged.
#[test]
fn worker_respawn_preserves_bit_parity_at_fixed_thread_count() {
    use std::time::Duration;
    let mut rng = Rng::new(0x9E59A);
    let x: Vec<f64> = (0..9000).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..9000).map(|_| rng.normal()).collect();
    // n >= threshold: the request shards across all three workers.
    let input = SharedInput::dot(&x, &y);
    let clean = DotService::new(serve_cfg(3, 2048)).unwrap();
    let want = clean.submit(&input.view()).unwrap();
    let injector = FaultInjector::new(FaultPlan::none().with(FaultSite::WorkerPanic, 1));
    let asy = AsyncDotService::new_with_faults(
        serve_cfg(3, 2048),
        AsyncOptions::default(),
        Some(injector.clone()),
    )
    .unwrap();
    match asy
        .submit(input.clone())
        .unwrap()
        .wait_timed_for(Duration::from_secs(30))
    {
        Some(Err(e)) => {
            assert!(e.to_string().contains("panic"), "typed worker-panic error, got: {e}")
        }
        Some(Ok(_)) => panic!("the faulted dispatch must fail: its worker died"),
        None => panic!("faulted request hung instead of resolving"),
    }
    for round in 0..4 {
        let (got, _) = asy
            .submit(input.clone())
            .unwrap()
            .wait_timed_for(Duration::from_secs(30))
            .unwrap_or_else(|| panic!("post-respawn round {round} hung"))
            .unwrap_or_else(|e| panic!("post-respawn round {round} failed: {e}"));
        assert_eq!(got.value.to_bits(), want.value.to_bits(), "round {round}");
        assert_eq!(got.path, want.path, "round {round}");
    }
    assert_eq!(injector.fired(FaultSite::WorkerPanic), 1);
}

/// An injector compiled in with an empty plan is bit-invisible: the full
/// async pipeline produces bit-identical results (values and exec paths)
/// with and without it, and the injector confirms nothing ever fired.
#[test]
fn idle_fault_injector_is_bit_invisible() {
    let mut rng = Rng::new(0x1D1E);
    let shared: Vec<SharedInput> = [64usize, 2047, 2048, 4096, 300]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            if i % 2 == 0 {
                let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                SharedInput::dot(&x, &y)
            } else {
                SharedInput::sum(&x)
            }
        })
        .collect();
    let plain = AsyncDotService::new(serve_cfg(2, 2048), AsyncOptions::default()).unwrap();
    let want = plain.submit_wait(&shared).unwrap();
    let injector = FaultInjector::new(FaultPlan::none());
    let idle = AsyncDotService::new_with_faults(
        serve_cfg(2, 2048),
        AsyncOptions::default(),
        Some(injector.clone()),
    )
    .unwrap();
    let got = idle.submit_wait(&shared).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.value.to_bits(), g.value.to_bits(), "n={}", w.n);
        assert_eq!(w.path, g.path);
    }
    assert_eq!(injector.total_fired(), 0, "an empty plan must never fire");
}

/// Scheduling never forks the numerics: the same deterministic request
/// stream, folded in submission order, yields a bit-identical checksum —
/// and the same fused/sharded path split — whether the queue drains FIFO,
/// weighted-fair, or with the tenant priorities reversed, across random
/// weights, mixtures, and operand seeds at a fixed thread count. The QoS
/// layer decides *where and when* a request runs, never *what* it
/// computes (queue.rs `QosPolicy` contract).
#[test]
fn scheduling_interleavings_preserve_bit_parity_at_fixed_threads() {
    use kahan_ecm::serve::{run_interleaving_checksum, MixEntry, OperandPool, QosPolicy};

    property("interleaving bit-parity", 4, |g| {
        let wa = g.u64(1, 5);
        let wb = g.u64(1, 5);
        let mix = vec![
            MixEntry { n: g.usize(128, 1024), weight: 0.75 },
            MixEntry { n: g.usize(4096, 12288), weight: 0.25 },
        ];
        let requests = g.usize(24, 48);
        let seed = g.u64(1, 1 << 40);
        let policies: Vec<Option<QosPolicy>> = vec![
            None,
            Some(QosPolicy::parse(&format!("a:{wa},b:{wb}")).unwrap()),
            Some(QosPolicy::parse(&format!("a:{wb},b:{wa}")).unwrap()),
        ];
        let mut reports = Vec::new();
        for qos in policies {
            let asy = AsyncDotService::new_with_qos(
                serve_cfg(2, 2048),
                AsyncOptions::default(),
                qos,
                None,
            )
            .unwrap();
            let ops = OperandPool::generate(&mix, seed, asy.service().pool());
            reports.push(run_interleaving_checksum(&asy, &mix, &ops, requests, 2, seed).unwrap());
        }
        let fifo = &reports[0];
        assert_eq!(fifo.fused + fifo.sharded, requests);
        for r in &reports[1..] {
            assert_eq!(
                r.checksum.to_bits(),
                fifo.checksum.to_bits(),
                "scheduling must never fork the numerics: {reports:?}"
            );
            assert_eq!((r.fused, r.sharded), (fifo.fused, fifo.sharded));
        }
    });
}

/// The deficit-round-robin core is weight-fair: over a permanently
/// backlogged tenant set with random weights and tenant counts, each
/// tenant's share of drain slots converges to `weight / Σ weights`
/// (within the quantum granularity), every slot is filled, and no
/// backlogged tenant is ever starved. `drr_select` is pure, so the
/// invariant is pinned without a running service.
#[test]
fn drr_fairness_share_converges_to_weights() {
    use kahan_ecm::serve::{QosPolicy, TenantClass};
    use std::collections::BTreeMap;

    property("DRR share converges to weights", 40, |g| {
        let tenants = g.usize(2, 5);
        let classes: Vec<TenantClass> = (0..tenants)
            .map(|i| TenantClass {
                name: format!("t{i}"),
                weight: g.u64(1, 6) as u32,
                quota: None,
            })
            .collect();
        let weight_sum: u64 = classes.iter().map(|c| u64::from(c.weight)).sum();
        let policy = QosPolicy::new(classes.clone());
        // A whole number of credit rounds per batch keeps the quantum
        // granularity out of the measured shares; carryover covers the
        // rest (the queue-level batch_max is tuned the same way).
        let batch_max = (weight_sum as usize) * g.usize(1, 5);
        let rounds = 256usize;
        let mut deficits = BTreeMap::new();
        let pending: BTreeMap<u32, usize> =
            (0..tenants as u32).map(|t| (t, 1 << 20)).collect();
        let mut taken = vec![0u64; tenants];
        for _ in 0..rounds {
            for &t in &policy.drr_select(&mut deficits, &pending, batch_max) {
                taken[t as usize] += 1;
            }
        }
        let total: u64 = taken.iter().sum();
        assert_eq!(total as usize, rounds * batch_max, "a backlogged set fills every slot");
        for (i, c) in classes.iter().enumerate() {
            assert!(taken[i] > 0, "tenant {i} (weight {}) must never starve", c.weight);
            let share = taken[i] as f64 / total as f64;
            let want = u64::from(c.weight) as f64 / weight_sum as f64;
            assert!(
                (share - want).abs() < 0.02,
                "tenant {i} share {share:.4} should converge to weight share {want:.4}"
            );
        }
    });
}

/// Quota accounting is conservative — no request is ever double-counted
/// and none is lost: over random quotas, burst sizes, and operand sizes,
/// every non-blocking submission lands in exactly one bucket (accepted,
/// quota-shed, or busy-shed), the tenant counters agree with the caller's
/// own bookkeeping, and at quiescence every admitted request has
/// completed. With quota 0, every submission sheds at admission.
#[test]
fn quota_accounting_never_double_counts_a_shed_request() {
    use kahan_ecm::serve::{QosPolicy, TenantClass, TrySubmit};
    use std::time::{Duration, Instant};

    property("quota accounting conservation", 8, |g| {
        let quota = g.usize(0, 3);
        let offered = g.usize(6, 18);
        let n = g.usize(64, 512);
        let policy = QosPolicy::new(vec![TenantClass {
            name: "only".to_string(),
            weight: 1,
            quota: Some(quota),
        }]);
        let asy = AsyncDotService::new_with_qos(
            serve_cfg(2, 4096),
            AsyncOptions::default(),
            Some(policy),
            None,
        )
        .unwrap();
        let x = g.vec_f64_log(n, -8, 8);
        let y = g.vec_f64_log(n, -8, 8);
        let input = SharedInput::dot(&x, &y);
        let (mut accepted, mut qshed, mut busy) = (Vec::new(), 0u64, 0u64);
        for _ in 0..offered {
            match asy
                .try_submit_with_opts(input.clone(), Instant::now(), None, 0, false)
                .unwrap()
            {
                TrySubmit::Accepted(h) => accepted.push(h),
                TrySubmit::Quota => qshed += 1,
                TrySubmit::Busy => busy += 1,
            }
        }
        assert_eq!(
            accepted.len() as u64 + qshed + busy,
            offered as u64,
            "every submission lands in exactly one bucket"
        );
        if quota == 0 {
            assert!(accepted.is_empty(), "quota 0 admits nothing");
            assert_eq!(qshed, offered as u64);
        }
        for h in &accepted {
            h.wait_timed_for(Duration::from_secs(30))
                .expect("admitted request hung")
                .expect("admitted request failed");
        }
        let rows = asy.tenant_stats();
        assert_eq!(rows.len(), 1);
        let row = rows[0];
        assert_eq!(row.admitted, accepted.len() as u64, "admitted matches the caller's count");
        assert_eq!(row.quota_shed, qshed, "each shed is counted exactly once");
        assert_eq!(row.completed, row.admitted, "at quiescence every admission completes");
        assert_eq!(row.deadline_shed, 0);
    });
}

/// The result-cache parity contract (docs/ARCHITECTURE.md §3c): a cache
/// hit replays exactly the bits the recomputation it stands in for
/// produced — the value AND the execution path — at every thread count,
/// on both sides of the shard threshold. The computed miss, the memoized
/// hit, and a cache-free synchronous reference are compared via
/// `to_bits`, and the counter deltas pin exactly one miss then one hit
/// per pair.
#[test]
fn cached_results_are_bit_identical_to_recomputation() {
    let mut rng = Rng::new(0x9C5E);
    let threshold = 2048usize;
    let data: Vec<(Vec<f64>, Vec<f64>)> = [17usize, 600, 2047, 2048, 4097]
        .iter()
        .map(|&n| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    for threads in [1usize, 2, 3] {
        let sync = DotService::new(serve_cfg(threads, threshold)).unwrap();
        let asy =
            AsyncDotService::new(serve_cfg(threads, threshold), AsyncOptions::default()).unwrap();
        for (x, y) in &data {
            let want = sync.submit_batch(&[KernelInput::Dot(x, y)]).unwrap().remove(0);
            let a = asy.register_operand(arc_operand(x)).unwrap();
            let b = asy.register_operand(arc_operand(y)).unwrap();
            let before = asy.cache_stats();
            let miss = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
            let hit = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
            let after = asy.cache_stats();
            for (label, got) in [("computed miss", &miss), ("memoized hit", &hit)] {
                assert_eq!(
                    got.value.to_bits(),
                    want.value.to_bits(),
                    "{label} value n={} T={threads}",
                    want.n
                );
                assert_eq!(got.path, want.path, "{label} path n={} T={threads}", want.n);
                assert_eq!(got.n, want.n);
            }
            assert_eq!(after.lookups - before.lookups, 2, "one probe per handle submit");
            assert_eq!(after.misses - before.misses, 1, "the first submit computes");
            assert_eq!(after.hits - before.hits, 1, "the second submit replays");
        }
        let s = asy.cache_stats();
        assert_eq!(s.hits + s.misses, s.lookups, "the counter partition is exact");
    }
}

/// Store eviction is least-recently-USED, not first-registered. A crisp
/// deterministic scenario first (a `lookup` refresh protects the oldest
/// registration, so capacity pressure evicts its younger-but-untouched
/// neighbor), then a randomized register/lookup/release workload against
/// a 4-slot store is checked op-by-op against an explicit LRU reference
/// model — residency set, eviction victims, and conserved counters.
#[test]
fn operand_store_eviction_follows_lru_order() {
    use std::collections::HashMap;

    // v0 registered first, then refreshed: the eviction forced by v3 must
    // take the least-recently-used v1, not the oldest-registered v0.
    let vecs: Vec<Vec<f64>> = (0..4)
        .map(|i| (0..8).map(|j| (100 * i + j) as f64).collect())
        .collect();
    let store = OperandStore::new(3 * 64);
    let h: Vec<u64> = vecs
        .iter()
        .take(3)
        .map(|v| store.register(arc_operand(v)).unwrap().handle)
        .collect();
    assert!(store.lookup(h[0]).is_some(), "refresh the oldest registration");
    let h3 = store.register(arc_operand(&vecs[3])).unwrap().handle;
    assert!(store.contains(h[0]), "the refreshed entry survives");
    assert!(!store.contains(h[1]), "the least-recently-used entry is the victim");
    assert!(store.contains(h[2]) && store.contains(h3));
    assert_eq!(store.stats().evictions, 1);

    property("operand store LRU model", 40, |g| {
        const N: usize = 8; // 64 bytes per operand: exactly one slot
        const SLOTS: u64 = 4;
        let store = OperandStore::new(SLOTS as usize * N * 8);
        let pool: Vec<Vec<f64>> = (0..7)
            .map(|i| (0..N).map(|j| (i * N + j) as f64 + g.normal()).collect())
            .collect();
        let handles: Vec<u64> = pool.iter().map(|v| handle_of(&operand_digest(v))).collect();
        let mut model: HashMap<u64, u64> = HashMap::new();
        let mut clock = 0u64;
        let mut evictions = 0u64;
        for _ in 0..40 {
            let idx = g.usize(0, pool.len() - 1);
            let handle = handles[idx];
            match g.u64(0, 3) {
                0 | 1 => {
                    let out = store.register(arc_operand(&pool[idx])).unwrap();
                    assert_eq!(out.handle, handle, "handles are a pure function of contents");
                    clock += 1;
                    let fresh = !model.contains_key(&handle);
                    assert_eq!(out.fresh, fresh, "fresh iff not resident");
                    model.insert(handle, clock);
                    while model.len() as u64 > SLOTS {
                        let victim = *model
                            .iter()
                            .filter(|&(&k, _)| k != handle)
                            .min_by_key(|(_, &stamp)| stamp)
                            .map(|(k, _)| k)
                            .unwrap();
                        model.remove(&victim);
                        evictions += 1;
                    }
                }
                2 => {
                    let resident = model.contains_key(&handle);
                    assert_eq!(store.lookup(handle).is_some(), resident);
                    if resident {
                        clock += 1;
                        model.insert(handle, clock);
                    }
                }
                _ => {
                    assert_eq!(store.release(handle), model.remove(&handle).is_some());
                }
            }
            for h in &handles {
                assert_eq!(store.contains(*h), model.contains_key(h), "residency model drift");
            }
        }
        let s = store.stats();
        assert_eq!(s.entries, model.len() as u64);
        assert_eq!(s.resident_bytes, model.len() as u64 * (N as u64) * 8);
        assert_eq!(s.evictions, evictions, "every eviction victim matched the model");
    });
}

/// Handle lifecycle is collision-free and content-pure: a handle equals
/// the documented SHA-256 truncation of its operand bits, re-registration
/// after release yields the same handle fresh again, distinct contents
/// never share a handle, a released handle fails a submit with the typed
/// first-unknown error — and once re-registered, the still-memoized
/// result replays bit-identically (the cache accelerates resident
/// operands; resolution, not the cache, decides liveness).
#[test]
fn released_handles_reregister_collision_free() {
    property("handle release/reuse lifecycle", 20, |g| {
        let n = g.usize(4, 600);
        let x: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let asy = AsyncDotService::new(serve_cfg(2, 2048), AsyncOptions::default()).unwrap();

        let a = asy.register_operand(arc_operand(&x)).unwrap();
        let b = asy.register_operand(arc_operand(&y)).unwrap();
        assert_eq!(a.handle, handle_of(&operand_digest(&x)), "documented derivation");
        assert!(a.fresh && b.fresh);
        assert_ne!(a.handle, b.handle, "distinct contents, distinct handles");

        let first = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();

        // Release is idempotent and a released handle is typed-unknown,
        // reported first (a before b), even though (a, b) is memoized.
        assert!(asy.release_operand(a.handle));
        assert!(!asy.release_operand(a.handle), "second release is a no-op");
        let err = asy
            .submit_handles(a.handle, b.handle)
            .err()
            .expect("a released handle must fail to resolve");
        match err {
            BackendError::UnknownHandle { handle } => assert_eq!(handle, a.handle),
            other => panic!("expected UnknownHandle, got {other:?}"),
        }

        // Same contents, same handle, fresh again — and the memoized
        // result for the re-registered pair replays bit-identically.
        let again = asy.register_operand(arc_operand(&x)).unwrap();
        assert_eq!(again.handle, a.handle, "content-derived handles are stable");
        assert!(again.fresh, "release made the slot re-registerable");
        let hits_before = asy.cache_stats().hits;
        let replay = asy.submit_handles(a.handle, b.handle).unwrap().wait().unwrap();
        assert_eq!(replay.value.to_bits(), first.value.to_bits());
        assert_eq!(replay.path, first.path);
        assert_eq!(asy.cache_stats().hits, hits_before + 1, "served from the cache");
    });
}

/// The certified per-request error bound (revision 1.4, `FLAG_ERRBOUND`)
/// is sound across generator conditionings: the bound an opted-in
/// request carries dominates the request's true error against the exact
/// ground truth of `accuracy/exact.rs`, stays within the same
/// `8·eps·Σ|x·y|` envelope the accuracy tests pin for the compensated
/// rung, and rides along without touching the value — an opted-out
/// submit of the same input returns the identical bits with no bound
/// attached (the pre-rev-1.4 response).
#[test]
fn certified_error_bound_is_sound_within_the_paper_envelope() {
    use std::time::Instant;
    property("certified error bound envelope", 25, |g| {
        let n = g.usize(2, 300) * 2 + 4; // even, >= 8
        let ce = g.f64_range(2.0, 30.0);
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let (x, y, exact) = ill_conditioned_dot(n, 2f64.powf(ce), &mut rng);
        let cond_sum: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let envelope = 8.0 * f64::EPSILON * cond_sum;
        let asy = AsyncDotService::new(serve_cfg(2, 2048), AsyncOptions::default()).unwrap();
        let input = SharedInput::dot(&x, &y);
        let bounded = asy
            .submit_with_opts(input.clone(), Instant::now(), None, 0, true)
            .unwrap()
            .wait()
            .unwrap();
        let bound = bounded.err_bound.expect("opted-in requests carry a bound");
        assert!(bound.is_finite() && bound >= 0.0);
        assert!(
            (bounded.value - exact).abs() <= bound,
            "bound must dominate the true error: err {} > bound {bound} (n = {n}, cond 2^{ce:.1})",
            (bounded.value - exact).abs()
        );
        assert!(
            bound <= envelope,
            "bound {bound} outside the 8·eps envelope {envelope} (n = {n}, cond 2^{ce:.1})"
        );
        let plain = asy
            .submit_with_opts(input, Instant::now(), None, 0, false)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(plain.err_bound, None, "opting out is the pre-rev-1.4 response");
        assert_eq!(
            plain.value.to_bits(),
            bounded.value.to_bits(),
            "the bound rides along; the served value is untouched"
        );
    });
}

/// Verify-on-hit and on-lookup scrubbing are bit-transparent on a clean
/// store (the integrity layer's false-positive contract): a service at
/// `verify_hit_rate` 1 with digest re-checks armed serves exactly the
/// bits of an unverified service over the same handle workload, the
/// verified counter equals the hit count (rate 1 samples every hit), no
/// cache entry is ever poisoned, and no resident operand is ever
/// quarantined — while the rate-0 service never touches the verifier at
/// all (the unverified pipeline stays the revision-1.3 fast path).
#[test]
fn verified_cache_hits_change_no_bits_on_a_clean_store() {
    property("verify-on-hit clean parity", 10, |g| {
        let n = g.usize(8, 900);
        let pairs: Vec<(Vec<f64>, Vec<f64>)> = (0..3)
            .map(|_| {
                (
                    (0..n).map(|_| g.normal()).collect(),
                    (0..n).map(|_| g.normal()).collect(),
                )
            })
            .collect();
        let mut verified_cfg = serve_cfg(2, 2048);
        verified_cfg.verify_hit_rate = 1.0;
        let base = AsyncDotService::new(serve_cfg(2, 2048), AsyncOptions::default()).unwrap();
        let checked = AsyncDotService::new(verified_cfg, AsyncOptions::default()).unwrap();
        checked.store().set_verify_on_lookup(true);
        for (x, y) in &pairs {
            let a0 = base.register_operand(arc_operand(x)).unwrap().handle;
            let b0 = base.register_operand(arc_operand(y)).unwrap().handle;
            let a1 = checked.register_operand(arc_operand(x)).unwrap().handle;
            let b1 = checked.register_operand(arc_operand(y)).unwrap().handle;
            assert_eq!((a0, b0), (a1, b1), "content-addressed handles agree");
            for round in 0..3 {
                let want = base.submit_handles(a0, b0).unwrap().wait().unwrap();
                let got = checked.submit_handles(a1, b1).unwrap().wait().unwrap();
                assert_eq!(
                    got.value.to_bits(),
                    want.value.to_bits(),
                    "verification changes no bits (round {round})"
                );
                assert_eq!(got.path, want.path);
            }
        }
        let base_cache = base.cache_stats();
        let cache = checked.cache_stats();
        assert_eq!(cache.hits, base_cache.hits, "identical workloads, identical hit counts");
        assert_eq!(cache.verified, cache.hits, "rate 1 samples every hit");
        assert_eq!(cache.poisoned, 0, "a clean cache never trips the verifier");
        assert_eq!(base_cache.verified, 0, "rate 0 never invokes the verifier");
        assert_eq!(base_cache.poisoned, 0);
        let scrubbed = checked.store().stats();
        assert!(scrubbed.scrub_verified > 0, "on-lookup scrubbing actually ran");
        assert_eq!(scrubbed.scrub_quarantined, 0, "no false-positive quarantines");
        let unscrubbed = base.store().stats();
        assert_eq!(unscrubbed.scrub_verified, 0, "scrubbing off means no digest re-checks");
        assert_eq!(unscrubbed.scrub_quarantined, 0);
    });
}
