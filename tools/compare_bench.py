#!/usr/bin/env python3
"""Compare two BENCH_summary.json perf-trajectory artifacts.

Usage:
    python3 tools/compare_bench.py --baseline PREV.json --current CUR.json \
        --out BENCH_compare.json [--strict]

Compares the `headline` metrics of the current run against a previous-run
baseline with *noise-aware relative thresholds*: the CI smoke runners are
shared machines, so single-run swings of tens of percent are ordinary and
only large, direction-aware moves are called regressions. The verdict is
written to a machine-readable BENCH_compare.json and summarized on stdout.

Exit status: 0 unless --strict is given and at least one metric regressed.
A missing/unreadable baseline (first run, expired artifact) is not an
error: the verdict is "no-baseline" and the exit status is 0, so the CI
step degrades gracefully.
"""

import argparse
import json
import sys

# metric -> (direction, relative tolerance). Tolerances are deliberately
# loose: a shared smoke runner's timing wobbles, and this gate exists to
# catch step-function regressions (a kernel falling off its fast path, a
# scheduler serializing), not single-digit-percent drift.
METRICS = {
    "native_best_mflops": ("higher", 0.35),
    "native_best_kahan_dot_mflops": ("higher", 0.35),
    "scaling_kahan_dot_simd_peak_mflops": ("higher", 0.35),
    "serving_reqs_per_s": ("higher", 0.40),
    "serving_mflops": ("higher", 0.40),
    "serving_p99_us": ("lower", 0.50),
    "serving_async_p99_us": ("lower", 0.50),
    "serving_async_reqs_per_s": ("higher", 0.40),
    "serving_measured_p1_mflops": ("higher", 0.35),
    # The TCP loopback path adds syscall + loopback-stack latency on top of
    # the queue path, so its tail is the wobbliest metric of the set.
    "serving_wire_p99_us": ("lower", 0.60),
    "serving_wire_reqs_per_s": ("higher", 0.40),
}

# (prefix, suffix) -> rule, for headline families whose middle segment is
# dynamic. serving_tenant_<name>_p99_us carries one weighted-scenario tail
# per configured tenant class; as loose as the wire tail, because the QoS
# scheduler shares the smoke runner's wobble.
PREFIX_METRICS = [
    ("serving_tenant_", "_p99_us", ("lower", 0.60)),
]


def rule_for(name):
    """The (direction, tolerance) rule for a headline metric, or None if
    the metric never feeds the perf verdict."""
    if name in METRICS:
        return METRICS[name]
    for prefix, suffix, rule in PREFIX_METRICS:
        if name.startswith(prefix) and name.endswith(suffix):
            return rule
    return None


# Chaos-run accounting (the serving document's `chaos` block and the
# `serving_chaos_*` headline entries) is deliberately absent from the
# allowlist above: fault-injection runs measure robustness, not
# performance — their latency and throughput are dominated by injected
# stalls and shed requests, so comparing them across runs would only add
# noise to the perf verdict. Their gates (hung_requests == 0, recovery
# verified, tenant isolation) are hard-checked by tools/validate_bench.py
# instead.
assert not any(m.startswith("serving_chaos") for m in METRICS), \
    "chaos accounting must never feed perf verdicts"
assert rule_for("serving_chaos_total_injected") is None

# The zipf block's `serving_zipf_*` entries are likewise excluded: the
# speedup is a loopback A/B ratio whose baseline pass ships megabytes per
# request through the shared runner's loopback stack, so its run-to-run
# swing dwarfs any real regression. Its correctness gates (bit-parity,
# hits + misses == lookups) are hard-checked by tools/validate_bench.py.
assert not any(m.startswith("serving_zipf") for m in METRICS), \
    "zipf accounting must never feed perf verdicts"
assert rule_for("serving_zipf_speedup") is None

# The integrity block's `serving_integrity_*` entries are excluded too:
# a corruption-injection run measures detection coverage, not speed — its
# throughput is dominated by forced recomputation, re-registration, and
# retry round-trips. Its gates (total_detected == total_injected,
# delivered_corrupt == 0, a clean control pass with zero false positives
# and bit-parity) are hard-checked by tools/validate_bench.py.
assert not any(m.startswith("serving_integrity") for m in METRICS), \
    "integrity accounting must never feed perf verdicts"
assert rule_for("serving_integrity_total_injected") is None


def load_summary(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if doc.get("schema") != "kahan-ecm-bench-summary/v1":
        return None
    return doc


def compare_metric(name, base, cur):
    direction, tolerance = rule_for(name)
    if base <= 0:
        return {"metric": name, "baseline": base, "current": cur,
                "ratio": None, "verdict": "skipped"}
    ratio = cur / base
    if direction == "higher":
        if ratio < 1.0 - tolerance:
            verdict = "regressed"
        elif ratio > 1.0 + tolerance:
            verdict = "improved"
        else:
            verdict = "ok"
    else:  # lower is better
        if ratio > 1.0 + tolerance:
            verdict = "regressed"
        elif ratio < 1.0 - tolerance:
            verdict = "improved"
        else:
            verdict = "ok"
    return {"metric": name, "baseline": base, "current": cur,
            "ratio": ratio, "verdict": verdict}


def main(argv):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline", required=True,
                    help="previous-run BENCH_summary.json (may be missing)")
    ap.add_argument("--current", required=True,
                    help="this run's BENCH_summary.json")
    ap.add_argument("--out", required=True,
                    help="write the BENCH_compare.json verdict here")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any metric regressed "
                         "(default: warn only — smoke runners are shared)")
    args = ap.parse_args(argv)

    current = load_summary(args.current)
    if current is None:
        raise SystemExit(f"compare_bench: FAIL: cannot read current summary "
                         f"{args.current}")
    baseline = load_summary(args.baseline)

    result = {
        "schema": "kahan-ecm-bench-compare/v1",
        "baseline_path": args.baseline,
        "comparisons": [],
    }
    if baseline is None:
        result["verdict"] = "no-baseline"
        print(f"compare_bench: no usable baseline at {args.baseline}; "
              f"recording current headline only")
    else:
        base_h, cur_h = baseline["headline"], current["headline"]
        names = sorted(set(METRICS) |
                       {n for n in cur_h if rule_for(n) is not None})
        for name in names:
            if name in base_h and name in cur_h:
                result["comparisons"].append(
                    compare_metric(name, base_h[name], cur_h[name]))
        verdicts = {c["verdict"] for c in result["comparisons"]}
        if "regressed" in verdicts:
            result["verdict"] = "regressed"
        elif not result["comparisons"]:
            result["verdict"] = "no-overlap"
        else:
            result["verdict"] = "ok"
        for c in result["comparisons"]:
            ratio = "-" if c["ratio"] is None else f"{c['ratio']:.3f}x"
            print(f"{c['verdict']:>9s}  {c['metric']:<40s} "
                  f"{c['baseline']:>12.1f} -> {c['current']:>12.1f}  ({ratio})")
        print(f"compare_bench: overall verdict: {result['verdict']}")
    result["current_headline"] = current["headline"]

    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    if args.strict and result["verdict"] == "regressed":
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
