#!/usr/bin/env python3
"""Synthetic-document tests for validate_bench.py and compare_bench.py.

Run directly (CI does): python3 tools/test_bench_tools.py

Each synthetic document is the minimal valid instance of its schema; the
tests then break one invariant at a time and require the validator to
reject it. This is what keeps the Rust emitters, the validators and CI
honest with each other: a schema change that forgets one of the three
shows up here or in the smoke job, not in a silently-green pipeline.
"""

import copy
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import compare_bench  # noqa: E402
import validate_bench  # noqa: E402


def synth_native():
    kernels = ["naive_dot.scalar", "kahan_dot.simd", "kahan_sum.unroll8"]
    return {
        "backend": "native",
        "avx2": False,
        "avx512": False,
        "freq_ghz": 3.0,
        "freq_source": "cpuinfo",
        "warmup": 1,
        "reps": 3,
        "results": [
            {"kernel": k, "n": 1024, "ws_bytes": 16384, "flops": 5120,
             "ns_min": 500.0, "ns_median": 600.0, "mflops": 1000.0,
             "gups": 2.0, "gbs": 32.0, "cycles_per_flop": 0.5,
             "cycles_per_update": 1.5}
            for k in kernels
        ],
    }


def synth_scaling(tmax=2):
    curves = []
    for k in ["naive_dot.simd", "kahan_dot.simd"]:
        curves.append({
            "kernel": k,
            "n": 262144,
            "points": [
                {"threads": t, "ns_min": 1000.0, "ns_median": 1100.0,
                 "mflops": 800.0 * t, "mflops_best": 900.0 * t,
                 "gups": 1.0 * t, "gbs": 16.0 * t,
                 "model_gups": 1.1 * t, "model_mflops": 850.0 * t}
                for t in range(1, tmax + 1)
            ],
        })
    return {
        "backend": "native-mt",
        "avx2": False,
        "avx512": False,
        "threads_max": tmax,
        "n": 262144,
        "freq_ghz": 3.0,
        "freq_source": "cpuinfo",
        "warmup": 1,
        "reps": 3,
        "machine_model": "HOST",
        "model_bw_gbs": 20.0,
        "scaling": curves,
        "sweep": [],
    }


def queue_row(p99, checksum, fused, sharded, requests):
    return {
        "requests": requests,
        "fused": fused,
        "sharded": sharded,
        "latency_ns": {"p50": p99 * 0.4, "p90": p99 * 0.8,
                       "p99": p99, "max": p99 * 1.5},
        "busy_ns": 4.0e7,
        "elapsed_ns": 6.0e7,
        "mflops": 900.0,
        "gups": 1.5,
        "reqs_per_s": 40000.0,
        "checksum": checksum,
        "max_queue_depth": 17,
        "dispatches": 12,
        "arrival_batches": 9,
        "pool_utilization": 0.8,
        "non_finite_latencies": 0,
    }


def synth_chaos():
    return {
        "seed": 1,
        "requests": 256,
        "completed_ok": 196,
        "deadline_shed": 40,
        "quota_shed": 4,
        "worker_panics": 14,
        "other_errors": 2,
        "hung_requests": 0,
        "injected": {"worker_panic": 1, "dispatcher_stall": 1,
                     "latch_wake_delay": 1, "socket_read_error": 0,
                     "socket_write_error": 0, "truncated_frame": 0,
                     "conn_drop_mid_batch": 0, "slow_client_writer": 0,
                     "quota_admission_reject": 4, "starvation_stall": 1,
                     "store_bit_flip": 0, "frame_crc_corrupt": 0,
                     "cache_poison": 0},
        "total_injected": 8,
        "recovery": {"verified": True, "latency_ns": 150000.0},
    }


def tenant_row(tenant, name, weight, quota, offered, admitted, quota_shed,
               p99, busy_shed=0, deadline_shed=0):
    completed = admitted - deadline_shed
    lat = {"p50": p99 * 0.4, "p99": p99, "max": p99 * 1.4} if completed \
        else {"p50": None, "p99": None, "max": None}
    return {
        "tenant": tenant, "name": name, "weight": weight, "quota": quota,
        "offered": offered, "admitted": admitted,
        "completed_ok": completed, "quota_shed": quota_shed,
        "busy_shed": busy_shed, "deadline_shed": deadline_shed,
        "latency_ns": lat,
    }


def synth_tenants():
    """The PR 8 `tenants` block: a 3:1 two-class policy, the uncontended
    weighted mixture, the noisy-neighbor run (heavy tenant a saturating and
    quota-shedding, light tenant b isolated), and bit-identical scheduling
    interleaving checksums."""
    return {
        "policy": [
            {"tenant": 0, "name": "a", "weight": 3, "quota": 48},
            {"tenant": 1, "name": "b", "weight": 1, "quota": 16},
        ],
        "scenarios": {
            "weighted": {
                "requests": 256, "rate_rps": 35000.0, "elapsed_ns": 6.0e7,
                "rows": [
                    tenant_row(0, "a", 3, 48, 192, 192, 0, 1.8e5),
                    tenant_row(1, "b", 1, 16, 64, 64, 0, 2.2e5),
                ],
            },
            "noisy": {
                "requests": 288, "rate_rps": 140000.0, "elapsed_ns": 8.0e7,
                "rows": [
                    tenant_row(0, "a", 3, 48, 256, 200, 56, 9.0e5),
                    tenant_row(1, "b", 1, 16, 32, 32, 0, 4.0e5),
                ],
            },
        },
        "interleaving": {
            "requests": 64,
            "fifo": 321.125, "weighted": 321.125, "reversed": 321.125,
            "match": True,
        },
    }


def zipf_pass(elapsed_ns, bytes_per_request, checksum, requests=400):
    return {
        "elapsed_ns": elapsed_ns,
        "reqs_per_s": requests / elapsed_ns * 1e9,
        "bytes_sent": bytes_per_request * requests,
        "bytes_per_request": float(bytes_per_request),
        "latency_p50_ns": elapsed_ns / requests * 0.8,
        "latency_p99_ns": elapsed_ns / requests * 2.5,
        "checksum": checksum,
    }


def synth_zipf():
    """The PR 9 `zipf` block: a 24-pair catalog of n=16384 operands drawn
    400 times under Zipf(1.2), served once by payload resubmission and once
    by registered handles, bit-identical, with conservative cache counters
    (every pair misses once, every repeat hits)."""
    checksum = 77.125
    return {
        "s": 1.2, "n": 16384, "catalog": 24, "requests": 400,
        "unique_pairs_drawn": 24,
        "baseline": zipf_pass(2.4e9, 20 + 4 + 16 * 16384, checksum),
        "handles": zipf_pass(0.4e9, 20 + 16, checksum),
        "speedup": 6.0,
        "register_ns": 6.0e7,
        "register_bytes": 48 * (20 + 4 + 8 * 16384),
        "value_mismatches": 0,
        "bit_parity": True,
        "cache": {
            "store_entries": 48,
            "store_resident_bytes": 48 * 16384 * 8,
            "store_registered": 48,
            "store_evictions": 0,
            "lookups": 400,
            "hits": 376,
            "misses": 24,
            "evictions": 0,
        },
    }


def synth_integrity():
    """The PR 10 `integrity` block: a 3-pair catalog of n=4096 operands
    drawn 12 times with one corruption armed at each integrity site —
    store bit-flip (quarantined on the digest re-check), frame CRC
    corruption (caught by the client's trailer verification), and
    result-cache poisoning (caught by verify-on-hit) — every injection
    detected, every request recovered bit-exactly, and a fault-free
    control pass with zero detections."""
    return {
        "seed": 41,
        "requests": 12,
        "catalog": 3,
        "n": 4096,
        "injected": {"store_bit_flip": 1, "frame_crc_corrupt": 1,
                     "cache_poison": 1},
        "total_injected": 3,
        "total_detected": 3,
        "detected": {"corrupt_frames": 1, "corrupt_operands": 1,
                     "cache_poisoned": 1},
        "delivered_corrupt": 0,
        "completed_ok": 12,
        "reregisters": 1,
        "retries": 2,
        "bound_missing": 0,
        "scrub": {"scrub_verified": 26, "scrub_quarantined": 1,
                  "scrub_passes": 1, "cache_verified": 8,
                  "cache_poisoned": 1},
        "clean": {"requests": 12, "detections": 0, "bit_parity": True},
    }


def wire_row(p99, checksum, fused, sharded, requests):
    row = queue_row(p99, checksum, fused, sharded, requests)
    row["connections"] = 2
    row["busy_retries"] = 3
    row["rate_rps"] = 35000.0
    return row


def synth_serving():
    requests, fused, sharded, checksum = 256, 229, 27, 123.456
    return {
        "subsystem": "serve",
        "backend": "native-mt",
        "kernel": "kahan_dot.simd",
        "threads": 2,
        "compensated": True,
        "shard_threshold": 65536,
        "threshold_source": "override",
        "mode": "closed",
        "rate_rps": None,
        "requests": requests,
        "batch": 32,
        "batches": 8,
        "seed": 1,
        "freq_ghz": 3.0,
        "freq_source": "cpuinfo",
        "mix": [{"n": 1024, "weight": 0.6}, {"n": 262144, "weight": 0.4}],
        "fused": fused,
        "sharded": sharded,
        "latency_ns": {"p50": 5.0e4, "p90": 1.0e5, "p99": 2.0e5, "max": 3.0e5},
        "busy_ns": 5.0e6,
        "elapsed_ns": 5.0e6,
        "updates": 100000,
        "flops": 500000,
        "mflops": 1000.0,
        "gups": 2.0,
        "reqs_per_s": 50000.0,
        "checksum": checksum,
        "queue": {"depth": 64, "batch_window_us": 100.0, "batch_max": 32},
        "open_loop": {
            "rate_rps": 35000.0,
            "sync": queue_row(4.0e6, checksum, fused, sharded, requests),
            "async": queue_row(2.5e6, checksum, fused, sharded, requests),
        },
        "wire": wire_row(3.0e6, checksum, fused, sharded, requests),
        "chaos": synth_chaos(),
        "tenants": synth_tenants(),
        "zipf": synth_zipf(),
        "integrity": synth_integrity(),
        "async_p99_ok": True,
        "calibration": {
            "measured": {"p1_gups": 1.8, "p1_mflops": 9000.0, "p1_n": 262144,
                         "dispatch_overhead_ns": 8000.0, "crossover": 65536},
            "model": {"p1_gups": 1.5, "dispatch_overhead_ns": 10000.0,
                      "crossover": 40960},
        },
    }


def expect_ok(validator, doc, label, *extra):
    note = validator(doc, *extra)
    assert isinstance(note, str) and note, label
    print(f"ok  {label}: {note}")


def expect_fail(validator, doc, label, *extra):
    try:
        validator(doc, *extra)
    except (AssertionError, KeyError):
        print(f"ok  {label} (rejected as expected)")
        return
    raise SystemExit(f"FAIL: {label}: validator accepted a broken document")


def mutate(doc, fn):
    d = copy.deepcopy(doc)
    fn(d)
    return d


def test_validators():
    expect_ok(validate_bench.validate_native, synth_native(), "native valid")
    expect_ok(validate_bench.validate_scaling, synth_scaling(), "scaling valid")
    serving = synth_serving()
    expect_ok(validate_bench.validate_serving, serving, "serving valid")
    expect_ok(validate_bench.validate_serving, serving,
              "serving valid under smoke check", True)

    def no_cal(d):
        del d["calibration"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_cal),
              "serving valid without calibration")

    def checksum_drift(d):
        d["open_loop"]["async"]["checksum"] += 1.0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, checksum_drift), "async checksum drift")

    def depth_overflow(d):
        d["open_loop"]["sync"]["max_queue_depth"] = d["queue"]["depth"] + 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, depth_overflow), "queue high-water > depth")

    def missing_queue(d):
        del d["queue"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, missing_queue), "missing queue block")

    def missing_async_row(d):
        del d["open_loop"]["async"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, missing_async_row), "missing async row")

    def slow_async(d):
        lat = d["open_loop"]["async"]["latency_ns"]
        lat["p99"] = d["open_loop"]["sync"]["latency_ns"]["p99"] * 2.0
        lat["max"] = lat["p99"] * 1.5
    # Warn-only mode accepts it; the smoke check must reject it.
    expect_ok(validate_bench.validate_serving, mutate(serving, slow_async),
              "slow async accepted without smoke check")
    expect_fail(validate_bench.validate_serving, mutate(serving, slow_async),
                "slow async rejected by smoke check", True)

    def calibrated_without_block(d):
        d["threshold_source"] = "calibrated"
        del d["calibration"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, calibrated_without_block),
                "calibrated source without calibration block")

    def bad_overhead(d):
        d["calibration"]["measured"]["dispatch_overhead_ns"] = 0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, bad_overhead), "non-positive overhead")

    def util_overflow(d):
        d["open_loop"]["async"]["pool_utilization"] = 1.5
    expect_fail(validate_bench.validate_serving,
                mutate(serving, util_overflow), "utilization > 1")

    # threshold_source "calibrated" with the block present is fine.
    def calibrated(d):
        d["threshold_source"] = "calibrated"
    expect_ok(validate_bench.validate_serving, mutate(serving, calibrated),
              "calibrated threshold source")

    # The wire row is optional in general but mandatory under the smoke
    # check (CI must not silently skip the TCP path).
    def no_wire(d):
        del d["wire"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_wire),
              "serving valid without wire row")
    expect_fail(validate_bench.validate_serving, mutate(serving, no_wire),
                "missing wire row rejected by smoke check", True)

    def wire_checksum_drift(d):
        d["wire"]["checksum"] += 1.0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, wire_checksum_drift),
                "wire checksum drift (socket determinism)")

    def wire_split_drift(d):
        d["wire"]["fused"] -= 1
        d["wire"]["sharded"] += 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, wire_split_drift), "wire traffic-split drift")

    def wire_no_connections(d):
        d["wire"]["connections"] = 0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, wire_no_connections), "wire with 0 connections")

    def wire_depth_overflow(d):
        d["wire"]["max_queue_depth"] = d["queue"]["depth"] + 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, wire_depth_overflow),
                "wire queue high-water > depth")

    # Chaos block (PR 7): optional, but when present its structural gates
    # are hard — no hung requests, buckets partition the run, per-site
    # counts reconcile, recovery verified.
    def no_chaos(d):
        del d["chaos"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_chaos),
              "serving valid without chaos block")

    def chaos_hung(d):
        d["chaos"]["hung_requests"] = 1
        d["chaos"]["completed_ok"] -= 1
    expect_fail(validate_bench.validate_serving, mutate(serving, chaos_hung),
                "chaos with a hung request")

    def chaos_bucket_leak(d):
        d["chaos"]["completed_ok"] -= 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, chaos_bucket_leak),
                "chaos buckets do not partition the requests")

    def chaos_injected_mismatch(d):
        d["chaos"]["total_injected"] += 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, chaos_injected_mismatch),
                "chaos per-site counts != total_injected")

    def chaos_no_faults(d):
        for site in d["chaos"]["injected"]:
            d["chaos"]["injected"][site] = 0
        d["chaos"]["total_injected"] = 0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, chaos_no_faults),
                "chaos run that injected nothing")

    def chaos_recovery_failed(d):
        d["chaos"]["recovery"]["verified"] = False
    expect_fail(validate_bench.validate_serving,
                mutate(serving, chaos_recovery_failed),
                "chaos recovery probe failed")

    def non_finite_latencies(d):
        d["open_loop"]["async"]["non_finite_latencies"] = 3
    expect_fail(validate_bench.validate_serving,
                mutate(serving, non_finite_latencies),
                "non-finite latencies in a healthy row")

    def chaos_quota_leak(d):
        d["chaos"]["quota_shed"] += 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, chaos_quota_leak),
                "chaos quota bucket breaks the partition")

    # Pre-PR-8 chaos blocks have no quota bucket; the partition check
    # defaults it to zero.
    def chaos_pre_pr8(d):
        d["chaos"]["completed_ok"] += d["chaos"].pop("quota_shed")
    expect_ok(validate_bench.validate_serving, mutate(serving, chaos_pre_pr8),
              "chaos block without quota bucket (pre-PR-8)")

    # Tenants block (PR 8): optional, but when present the QoS hard gates
    # apply — interleaving bit-parity, conservation per tenant, and
    # noisy-neighbor isolation.
    def no_tenants(d):
        del d["tenants"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_tenants),
              "serving valid without tenants block")

    def tenants_interleave_forked(d):
        inter = d["tenants"]["interleaving"]
        inter["weighted"] += 1e-9
        inter["match"] = False
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_interleave_forked),
                "interleaving checksums diverged")

    def tenants_interleave_lying_match(d):
        # The match flag says yes but the recorded floats disagree: the
        # validator must recompute, not trust the flag.
        d["tenants"]["interleaving"]["reversed"] += 1e-9
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_interleave_lying_match),
                "interleaving match flag contradicts the checksums")

    def tenants_heavy_never_shed(d):
        row = d["tenants"]["scenarios"]["noisy"]["rows"][0]
        row["quota_shed"] = 0
        row["admitted"] = row["offered"]
        row["completed_ok"] = row["offered"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_heavy_never_shed),
                "noisy scenario that never tripped the quota")

    def tenants_light_shed(d):
        row = d["tenants"]["scenarios"]["noisy"]["rows"][1]
        row["quota_shed"] = 1
        row["admitted"] -= 1
        row["completed_ok"] -= 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_light_shed),
                "heavy load leaking into the light tenant's quota")

    def tenants_light_tail_blowout(d):
        lat = d["tenants"]["scenarios"]["noisy"]["rows"][1]["latency_ns"]
        lat["p99"] = 1.0e10
        lat["max"] = 1.5e10
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_light_tail_blowout),
                "light tenant p99 blown out by the noisy neighbor")

    def tenants_admission_leak(d):
        d["tenants"]["scenarios"]["weighted"]["rows"][0]["admitted"] -= 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_admission_leak),
                "tenant admission buckets do not partition offered")

    def tenants_resolution_leak(d):
        d["tenants"]["scenarios"]["weighted"]["rows"][0]["completed_ok"] -= 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_resolution_leak),
                "admitted tenant request that never resolved")

    def tenants_policy_drift(d):
        d["tenants"]["scenarios"]["weighted"]["rows"][0]["weight"] = 2
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_policy_drift),
                "scenario row disagrees with the policy block")

    def tenants_null_latency_with_completions(d):
        d["tenants"]["scenarios"]["weighted"]["rows"][1]["latency_ns"] = \
            {"p50": None, "p99": None, "max": None}
    expect_fail(validate_bench.validate_serving,
                mutate(serving, tenants_null_latency_with_completions),
                "completed tenant row with null latency")

    # A fully-shed tenant row (zero completions, null latency) is legal in
    # the weighted scenario — the isolation gates only constrain the noisy
    # rows and the light tenants' uncontended tails.
    def tenants_zero_completion_row(d):
        d["tenants"]["scenarios"]["weighted"]["rows"][0] = \
            tenant_row(0, "a", 3, 48, 192, 0, 192, 0.0)
    expect_ok(validate_bench.validate_serving,
              mutate(serving, tenants_zero_completion_row),
              "fully quota-shed tenant row with null latency")

    # Zipf block (PR 9): optional, but when present the operand-store hard
    # gates apply — cached == recomputed bitwise, and cache counters that
    # conserve (hits + misses == lookups, every unique pair misses once).
    def no_zipf(d):
        del d["zipf"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_zipf),
              "serving valid without zipf block")

    def zipf_parity_broken(d):
        d["zipf"]["bit_parity"] = False
        d["zipf"]["value_mismatches"] = 3
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_parity_broken),
                "zipf cached pass diverged from the baseline")

    def zipf_lying_parity_flag(d):
        # The flag says parity but the checksums disagree: the validator
        # must recompute, not trust the flag.
        d["zipf"]["handles"]["checksum"] += 1e-9
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_lying_parity_flag),
                "zipf parity flag contradicts the checksums")

    def zipf_counter_leak(d):
        d["zipf"]["cache"]["hits"] -= 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_counter_leak),
                "zipf cache counters leak (hits + misses != lookups)")

    def zipf_no_hits(d):
        d["zipf"]["cache"]["hits"] = 0
        d["zipf"]["cache"]["misses"] = d["zipf"]["cache"]["lookups"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_no_hits),
                "zipf run that never hit the result cache")

    def zipf_misses_below_unique(d):
        gap = 4
        d["zipf"]["cache"]["misses"] -= gap
        d["zipf"]["cache"]["hits"] += gap
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_misses_below_unique),
                "zipf misses fewer than the unique pairs drawn")

    def zipf_handles_not_smaller(d):
        d["zipf"]["handles"]["bytes_per_request"] = \
            d["zipf"]["baseline"]["bytes_per_request"]
    expect_fail(validate_bench.validate_serving,
                mutate(serving, zipf_handles_not_smaller),
                "zipf handle frames as large as payload resubmission")

    def no_integrity(d):
        del d["integrity"]
    expect_ok(validate_bench.validate_serving, mutate(serving, no_integrity),
              "serving valid without integrity block")

    def integrity_undetected(d):
        d["integrity"]["total_injected"] += 1
        d["integrity"]["injected"]["store_bit_flip"] += 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_undetected),
                "integrity run with an undetected injection")

    def integrity_corrupt_delivered(d):
        d["integrity"]["delivered_corrupt"] = 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_corrupt_delivered),
                "integrity run that delivered a corrupt payload")

    def integrity_clean_false_positive(d):
        d["integrity"]["clean"]["detections"] = 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_clean_false_positive),
                "integrity clean pass raised a false positive")

    def integrity_clean_parity_broken(d):
        d["integrity"]["clean"]["bit_parity"] = False
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_clean_parity_broken),
                "integrity clean pass diverged bitwise")

    def integrity_bound_missing(d):
        d["integrity"]["bound_missing"] = 2
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_bound_missing),
                "integrity responses missing certified error bounds")

    def integrity_layer_counts_leak(d):
        d["integrity"]["detected"]["corrupt_frames"] += 1
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_layer_counts_leak),
                "integrity per-layer counts != total_detected")

    def integrity_scrub_never_ran(d):
        d["integrity"]["scrub"]["scrub_verified"] = 0
    expect_fail(validate_bench.validate_serving,
                mutate(serving, integrity_scrub_never_ran),
                "integrity run whose store scrubber never verified")


def write_docs(tmp, docs):
    paths = []
    for name, doc in docs.items():
        path = os.path.join(tmp, name)
        with open(path, "w") as f:
            json.dump(doc, f)
        paths.append(path)
    return paths


def test_merge_and_summary(tmp):
    paths = write_docs(tmp, {
        "BENCH_native.json": synth_native(),
        "BENCH_scaling.json": synth_scaling(),
        "BENCH_serving.json": synth_serving(),
    })
    merged = os.path.join(tmp, "BENCH_summary.json")
    rc = validate_bench.main(
        ["--expect-scaling-threads", "2", "--smoke-async-check",
         "--merge", merged] + paths)
    assert rc == 0
    with open(merged) as f:
        summary = json.load(f)
    h = summary["headline"]
    for key in ("serving_async_p99_us", "serving_sync_p99_us",
                "serving_measured_p1_mflops", "serving_reqs_per_s",
                "serving_wire_p99_us", "serving_wire_reqs_per_s",
                "serving_chaos_total_injected", "serving_chaos_hung",
                "serving_tenant_a_p99_us", "serving_tenant_b_p99_us",
                "serving_zipf_speedup", "serving_zipf_cache_hits",
                "serving_integrity_total_injected",
                "serving_integrity_total_detected",
                "serving_integrity_delivered_corrupt"):
        assert key in h, f"missing headline metric {key}: {sorted(h)}"
    # Re-validating the merged document must pass too.
    rc = validate_bench.main([merged])
    assert rc == 0
    print("ok  merge + headline + re-validate")
    return merged


def test_compare(tmp, merged):
    out = os.path.join(tmp, "BENCH_compare.json")
    # Identical runs: verdict ok.
    rc = compare_bench.main(["--baseline", merged, "--current", merged,
                             "--out", out])
    assert rc == 0
    with open(out) as f:
        verdict = json.load(f)
    assert verdict["verdict"] == "ok", verdict["verdict"]
    assert verdict["comparisons"], "no metrics compared"
    assert all(c["verdict"] == "ok" for c in verdict["comparisons"])
    # Chaos accounting is present in the headline but must never be
    # compared — robustness numbers are not perf metrics. Per-tenant tails
    # ARE compared, via the prefix rule (their names are dynamic).
    compared = {c["metric"] for c in verdict["comparisons"]}
    assert not any(m.startswith("serving_chaos") for m in compared), compared
    assert not any(m.startswith("serving_zipf") for m in compared), compared
    assert not any(m.startswith("serving_integrity") for m in compared), compared
    assert {"serving_tenant_a_p99_us", "serving_tenant_b_p99_us"} <= compared, \
        compared
    print("ok  compare identical -> ok (chaos + zipf + integrity excluded, "
          "tenant tails in)")

    # A big serving regression: warn by default, fail under --strict.
    with open(merged) as f:
        worse = json.load(f)
    worse["headline"]["serving_reqs_per_s"] *= 0.4
    worse["headline"]["serving_p99_us"] *= 3.0
    worse["headline"]["serving_tenant_b_p99_us"] *= 3.0
    worse_path = os.path.join(tmp, "BENCH_summary_worse.json")
    with open(worse_path, "w") as f:
        json.dump(worse, f)
    rc = compare_bench.main(["--baseline", merged, "--current", worse_path,
                             "--out", out])
    assert rc == 0, "default mode must warn, not fail"
    with open(out) as f:
        verdict = json.load(f)
    assert verdict["verdict"] == "regressed"
    regressed = {c["metric"] for c in verdict["comparisons"]
                 if c["verdict"] == "regressed"}
    assert {"serving_reqs_per_s", "serving_p99_us",
            "serving_tenant_b_p99_us"} <= regressed, regressed
    rc = compare_bench.main(["--baseline", merged, "--current", worse_path,
                             "--out", out, "--strict"])
    assert rc == 1, "--strict must fail on a regression"
    print("ok  compare regression -> warn / strict-fail")

    # Small drift inside the noise band stays ok.
    with open(merged) as f:
        drift = json.load(f)
    drift["headline"]["serving_reqs_per_s"] *= 0.9
    drift_path = os.path.join(tmp, "BENCH_summary_drift.json")
    with open(drift_path, "w") as f:
        json.dump(drift, f)
    rc = compare_bench.main(["--baseline", merged, "--current", drift_path,
                             "--out", out, "--strict"])
    assert rc == 0
    with open(out) as f:
        verdict = json.load(f)
    assert verdict["verdict"] == "ok"
    print("ok  compare noise-band drift -> ok")

    # Missing baseline degrades gracefully.
    rc = compare_bench.main(["--baseline", os.path.join(tmp, "nope.json"),
                             "--current", merged, "--out", out])
    assert rc == 0
    with open(out) as f:
        verdict = json.load(f)
    assert verdict["verdict"] == "no-baseline"
    assert verdict["current_headline"]
    print("ok  compare missing baseline -> no-baseline")


def main():
    test_validators()
    with tempfile.TemporaryDirectory() as tmp:
        merged = test_merge_and_summary(tmp)
        test_compare(tmp, merged)
    print("all bench-tool tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
