#!/usr/bin/env python3
"""Validate (and optionally merge) the kahan-ecm BENCH_*.json artifacts.

Usage:
    python3 tools/validate_bench.py [options] FILE...

Options:
    --merge OUT.json              after validating every input, write one
                                  merged BENCH_summary.json document (the
                                  machine-readable perf trajectory per run)
    --expect-scaling-threads N    additionally pin threads_max of the
                                  scaling document (CI smoke runs at 2)
    --smoke-async-check           hard-check the serving document's
                                  queue-mode overlap win (async p99 <=
                                  1.10 x sync p99 + 1.5 ms preemption
                                  slack) and require the loopback "wire"
                                  row (the smoke job must not silently
                                  skip the TCP path); only meant for the
                                  CI smoke configuration

Document kinds are recognized by shape:
    BENCH_native.json   -- `bench-native`  (backend "native", "results")
    BENCH_scaling.json  -- `bench-scale`   (backend "native-mt", "scaling")
    BENCH_serving.json  -- `serve-bench`   ("subsystem": "serve")
    BENCH_summary.json  -- a previous merge ("schema": "kahan-ecm-bench-summary/...")

Shared by .github/workflows/ci.yml and local runs, so the schema checks
cannot drift between the two. Exits non-zero with a message on the first
violation; prints one OK line per validated document.
"""

import argparse
import json
import sys


def fail(msg):
    raise SystemExit(f"validate_bench: FAIL: {msg}")


def kind_of(doc):
    if doc.get("subsystem") == "serve":
        return "serving"
    if doc.get("backend") == "native-mt" and "scaling" in doc:
        return "scaling"
    if doc.get("backend") == "native" and "results" in doc:
        return "native"
    if str(doc.get("schema", "")).startswith("kahan-ecm-bench-summary"):
        return "summary"
    fail("unrecognized document shape (keys: %s)" % sorted(doc))


def validate_native(doc):
    assert doc["backend"] == "native"
    assert doc["results"], "bench produced no results"
    assert isinstance(doc["avx2"], bool) and isinstance(doc["avx512"], bool)
    kernels = {r["kernel"] for r in doc["results"]}
    for want in ("naive_dot.scalar", "kahan_dot.simd", "kahan_sum.unroll8"):
        assert want in kernels, f"missing {want}"
    # The multi-accumulator AVX2 tier must be present whenever the host
    # has AVX2 (schema check, not a perf threshold).
    if doc["avx2"]:
        for style in ("avx2", "avx2u2", "avx2u4", "avx2u8"):
            for cls in ("naive_dot", "kahan_dot", "kahan_sum"):
                assert f"{cls}.{style}" in kernels, f"missing {cls}.{style}"
    else:
        assert not any(k.endswith("avx2u8") for k in kernels), kernels
    # The AVX-512 tier only ever appears in `--features avx512` builds.
    if not doc["avx512"]:
        assert not any("avx512" in k for k in kernels), kernels
    for r in doc["results"]:
        assert r["ns_min"] > 0 and r["mflops"] > 0, r
    assert doc["freq_ghz"] > 0, "clock fallback must always yield a value"
    return f"{len(doc['results'])} kernel results, avx2={doc['avx2']}, " \
           f"clock via {doc['freq_source']}"


def validate_scaling(doc, expect_threads=None):
    assert doc["backend"] == "native-mt"
    tmax = doc["threads_max"]
    assert tmax >= 1
    if expect_threads is not None:
        assert tmax == expect_threads, f"threads_max {tmax} != {expect_threads}"
    kernels = {c["kernel"] for c in doc["scaling"]}
    assert {"naive_dot.simd", "kahan_dot.simd"} <= kernels, kernels
    if doc["avx2"]:
        assert {"naive_dot.avx2u8", "kahan_dot.avx2u8"} <= kernels, kernels
    for curve in doc["scaling"]:
        pts = curve["points"]
        assert [p["threads"] for p in pts] == list(range(1, tmax + 1)), curve["kernel"]
        for p in pts:
            assert p["mflops"] > 0, (curve["kernel"], p)
            assert p["model_gups"] > 0, (curve["kernel"], p)
    assert doc["freq_ghz"] > 0
    return f"{len(doc['scaling'])} scaling curves, model bw " \
           f"{doc['model_bw_gbs']} GB/s, clock via {doc['freq_source']}"


def validate_latency_block(lat):
    assert 0 < lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"], lat


def validate_queue_row(row, requests):
    """One queue-mode open-loop row (the `sync` / `async` sides)."""
    assert row["requests"] == requests, row["requests"]
    assert row["fused"] + row["sharded"] == requests, row
    validate_latency_block(row["latency_ns"])
    assert row["mflops"] > 0 and row["gups"] > 0 and row["reqs_per_s"] > 0
    assert row["busy_ns"] > 0 and row["elapsed_ns"] > 0
    assert row["dispatches"] >= 1 and row["arrival_batches"] >= 1
    assert row["max_queue_depth"] >= 0
    assert 0 < row["pool_utilization"] <= 1.0, row["pool_utilization"]
    # Percentiles are computed over finite samples only; a healthy
    # (fault-free) row must not have dropped any. Absent in pre-PR-7
    # artifacts, hence the default.
    assert row.get("non_finite_latencies", 0) == 0, \
        f"{row['non_finite_latencies']} non-finite latencies in a healthy row"


def validate_wire_row(row, requests):
    """The optional `wire` row: the queue-row schema measured through the
    serve-net TCP front-end (docs/PROTOCOL.md), plus the wire-only fields.
    """
    validate_queue_row(row, requests)
    assert row["connections"] >= 1, row["connections"]
    assert row["busy_retries"] >= 0, row["busy_retries"]
    assert row["rate_rps"] > 0, row["rate_rps"]


def validate_crossover_value(value):
    # null encodes "never shard" (usize::MAX on the Rust side).
    assert value is None or (isinstance(value, int) and value >= 0), value


def validate_calibration(cal):
    measured = cal["measured"]
    assert measured["p1_gups"] > 0 and measured["p1_mflops"] > 0
    assert measured["p1_n"] >= 1
    assert measured["dispatch_overhead_ns"] >= 1
    validate_crossover_value(measured["crossover"])
    model = cal["model"]
    assert model["p1_gups"] is None or model["p1_gups"] > 0
    assert model["dispatch_overhead_ns"] > 0
    validate_crossover_value(model["crossover"])


def validate_chaos_block(chaos):
    """The optional `chaos` block (PR 7 schema): a seeded fault-injection
    run's accounting. Structural gates, not perf: every request must land
    in exactly one outcome bucket, nothing may hang, and the post-chaos
    recovery probe must have verified bit-parity. Chaos numbers never feed
    perf verdicts (tools/compare_bench.py ignores this block).
    """
    requests = chaos["requests"]
    assert requests >= 1, requests
    buckets = {k: chaos[k] for k in ("completed_ok", "deadline_shed",
                                     "worker_panics", "other_errors",
                                     "hung_requests")}
    # Tenant-QoS chaos runs (PR 8) add the quota-shed bucket: requests
    # refused at admission by a quota check or the injected
    # quota_admission_reject site. Absent in pre-PR-8 artifacts.
    buckets["quota_shed"] = chaos.get("quota_shed", 0)
    for name, count in buckets.items():
        assert count >= 0 and count == int(count), (name, count)
    assert sum(buckets.values()) == requests, \
        f"chaos buckets {buckets} must partition the {requests} requests"
    # The hard gate: a hung request means a ticket never resolved — the
    # resolve-exactly-once contract is broken and CI must go red.
    assert chaos["hung_requests"] == 0, \
        f"{chaos['hung_requests']} request(s) never resolved — pipeline wedged"
    injected = chaos["injected"]
    assert injected, "chaos block without per-site injection counts"
    for site, count in injected.items():
        assert count >= 0 and count == int(count), (site, count)
    assert sum(injected.values()) == chaos["total_injected"], \
        "per-site injection counts do not sum to total_injected"
    assert chaos["total_injected"] >= 1, \
        "a chaos run must actually inject faults"
    recovery = chaos["recovery"]
    assert recovery["verified"] is True, \
        "post-chaos recovery probe was not bit-identical to the sync path"
    assert recovery["latency_ns"] > 0, recovery


def validate_zipf_pass(p, label):
    """One pass of the `--zipf` scenario (baseline / handles)."""
    assert p["elapsed_ns"] > 0, (label, p["elapsed_ns"])
    assert p["reqs_per_s"] > 0, (label, p["reqs_per_s"])
    assert p["bytes_sent"] > 0 and p["bytes_per_request"] > 0, (label, p)
    assert 0 < p["latency_p50_ns"] <= p["latency_p99_ns"], (label, p)


def validate_zipf_block(zipf):
    """The optional `zipf` block (PR 9 schema): the resident-operand-store
    scenario — a skewed-popularity stream served twice, once re-shipping
    payloads and once by registered handle. Carries two hard gates:

    * bit-parity — every value of the handle (cached) pass must be
      bit-identical to the payload baseline over the same draw sequence;
      the cache may change *when* a value is computed, never *what* it is
      (docs/ARCHITECTURE.md §3c);
    * counter conservation — every result-cache lookup is a hit or a
      miss (`hits + misses == lookups`), and a skewed draw over a small
      catalog must actually produce hits.
    """
    requests = zipf["requests"]
    assert requests >= 1, requests
    catalog = zipf["catalog"]
    assert catalog >= 1, catalog
    assert zipf["n"] >= 1, zipf["n"]
    assert zipf["s"] >= 0, zipf["s"]
    assert 1 <= zipf["unique_pairs_drawn"] <= min(catalog, requests), zipf
    validate_zipf_pass(zipf["baseline"], "baseline")
    validate_zipf_pass(zipf["handles"], "handles")
    # Hard gate 1: cached == recomputed, bitwise, across the socket. The
    # floats round-trip bit-exactly through JSON, so equality here is the
    # Rust-side to_bits comparison.
    assert zipf["bit_parity"] is True, \
        "zipf bit-parity gate failed: the cached pass diverged from the " \
        "payload baseline"
    assert zipf["value_mismatches"] == 0, \
        f"{zipf['value_mismatches']} cached value(s) differed bitwise " \
        f"from their recomputed twins"
    assert zipf["baseline"]["checksum"] == zipf["handles"]["checksum"], \
        f"zipf checksums differ: baseline {zipf['baseline']['checksum']} " \
        f"/ handles {zipf['handles']['checksum']}"
    # Hard gate 2: counter conservation on the server's cache deltas.
    cache = zipf["cache"]
    for k, v in cache.items():
        assert v >= 0 and v == int(v), (k, v)
    assert cache["hits"] + cache["misses"] == cache["lookups"], \
        f"cache counters leak: {cache['hits']} hits + {cache['misses']} " \
        f"misses != {cache['lookups']} lookups"
    assert cache["lookups"] >= requests, \
        "every handle submission probes the cache exactly once at admission"
    assert cache["hits"] > 0, \
        "a Zipf draw over a small catalog produced no cache hits — the " \
        "scenario is not exercising the result cache"
    assert cache["misses"] >= zipf["unique_pairs_drawn"], \
        "each distinct pair must miss at least once before it can hit"
    # Registered twice per catalog pair (x and y), fresh registrations only.
    assert cache["store_registered"] >= 0, cache["store_registered"]
    assert cache["store_entries"] >= 1, cache["store_entries"]
    assert cache["store_resident_bytes"] >= zipf["n"] * 8, cache
    # The wire-traffic axis of the O(n) -> O(1) claim: a handle submit
    # must be smaller than re-shipping the operands.
    assert zipf["handles"]["bytes_per_request"] < \
        zipf["baseline"]["bytes_per_request"], \
        "handle submissions are not smaller than payload resubmission"
    assert zipf["register_ns"] > 0 and zipf["register_bytes"] > 0, zipf
    assert zipf["speedup"] > 0, zipf["speedup"]


def validate_integrity_block(integrity):
    """The optional `integrity` block (PR 10 schema): a seeded
    corruption-injection run through the full wire stack — store bit-flips,
    frame CRC corruption, and result-cache poisoning — plus a fault-free
    control pass at the same verification posture. Structural hard gates,
    never perf (tools/compare_bench.py ignores every serving_integrity_*
    headline):

    * detection completeness — every injected corruption is detected by
      exactly one layer (`total_detected == total_injected`);
    * zero corrupt deliveries — no response value ever diverged bitwise
      from the reference computation (`delivered_corrupt == 0`);
    * certified bounds — every opted-in response carried its error bound
      (`bound_missing == 0`);
    * zero false positives — the clean control pass detected nothing and
      stayed bit-identical to the reference (`clean.detections == 0`,
      `clean.bit_parity` true).
    """
    requests = integrity["requests"]
    assert requests >= 1, requests
    assert integrity["catalog"] >= 2, integrity["catalog"]
    assert integrity["n"] >= 1, integrity["n"]
    injected = integrity["injected"]
    assert injected, "integrity block without per-site injection counts"
    for site, count in injected.items():
        assert count >= 0 and count == int(count), (site, count)
    assert sum(injected.values()) == integrity["total_injected"], \
        "per-site injection counts do not sum to total_injected"
    assert integrity["total_injected"] >= 1, \
        "an integrity run must actually inject corruption"
    detected = integrity["detected"]
    for layer, count in detected.items():
        assert count >= 0 and count == int(count), (layer, count)
    assert sum(detected.values()) == integrity["total_detected"], \
        "per-layer detection counts do not sum to total_detected"
    # Hard gate 1: nothing slips past the detectors.
    assert integrity["total_detected"] == integrity["total_injected"], \
        f"{integrity['total_injected'] - integrity['total_detected']} " \
        f"injected corruption(s) went undetected"
    # Hard gate 2: detection always preceded delivery.
    assert integrity["delivered_corrupt"] == 0, \
        f"{integrity['delivered_corrupt']} corrupt payload(s) were " \
        f"delivered as results"
    assert integrity["completed_ok"] == requests, \
        "recovery incomplete: not every request eventually completed"
    assert integrity["reregisters"] >= 0
    assert integrity["retries"] >= detected["corrupt_frames"] + \
        detected["corrupt_operands"], \
        "client-visible detections must each have forced a retry"
    # Hard gate 3: certified error bounds on every opted-in response.
    assert integrity["bound_missing"] == 0, \
        f"{integrity['bound_missing']} response(s) lacked the requested " \
        f"certified error bound"
    scrub = integrity["scrub"]
    for k, v in scrub.items():
        assert v >= 0 and v == int(v), (k, v)
    assert scrub["scrub_verified"] >= 1, \
        "on-lookup scrubbing never verified a digest — the store " \
        "integrity layer is not armed"
    # Hard gate 4: the fault-free control pass at the same verification
    # posture raises no false positives and changes no bits.
    clean = integrity["clean"]
    assert clean["requests"] >= 1, clean
    assert clean["detections"] == 0, \
        f"clean control pass raised {clean['detections']} false positive(s)"
    assert clean["bit_parity"] is True, \
        "clean control pass diverged bitwise from the reference"


def validate_tenant_scenario(scn, policy, label):
    """One `--tenants` scenario (weighted / noisy): an offered rate plus
    one accounting + latency row per tenant class, aligned with the policy
    rows. The accounting must be conservative — every offered request
    lands in exactly one bucket, and every admitted request resolves.
    """
    assert scn["requests"] >= 1, (label, scn["requests"])
    assert scn["rate_rps"] > 0, (label, scn["rate_rps"])
    assert scn["elapsed_ns"] > 0, (label, scn["elapsed_ns"])
    rows = scn["rows"]
    assert len(rows) == len(policy), \
        f"{label}: {len(rows)} rows for {len(policy)} tenant classes"
    offered_total = 0
    for row, cls in zip(rows, policy):
        t = row["tenant"]
        assert (t, row["name"], row["weight"], row["quota"]) == \
            (cls["tenant"], cls["name"], cls["weight"], cls["quota"]), \
            f"{label}: row {t} disagrees with the policy block"
        offered_total += row["offered"]
        assert row["offered"] >= 1, (label, t, row["offered"])
        for k in ("admitted", "completed_ok", "quota_shed", "busy_shed",
                  "deadline_shed"):
            assert row[k] >= 0 and row[k] == int(row[k]), (label, t, k, row[k])
        # Admission conservation: shed-on-overload is typed and counted
        # exactly once, so the three buckets partition the offered load.
        assert row["admitted"] + row["quota_shed"] + row["busy_shed"] == \
            row["offered"], \
            f"{label}: tenant {t} admission buckets do not partition offered"
        # Resolution conservation: every admitted request resolved as a
        # success or an in-queue deadline shed (other errors fail the run).
        assert row["completed_ok"] + row["deadline_shed"] == row["admitted"], \
            f"{label}: tenant {t} resolved {row['completed_ok']} ok + " \
            f"{row['deadline_shed']} shed != admitted {row['admitted']}"
        lat = row["latency_ns"]
        if row["completed_ok"] > 0:
            assert all(lat[k] is not None for k in ("p50", "p99", "max")), \
                f"{label}: tenant {t} completed requests but has null latency"
            assert 0 < lat["p50"] <= lat["p99"] <= lat["max"], (label, t, lat)
        else:
            assert lat["p50"] is None, \
                f"{label}: tenant {t} has latency but zero completions"
    assert offered_total == scn["requests"], \
        f"{label}: per-tenant offered sums to {offered_total}, " \
        f"not {scn['requests']}"


def validate_tenants_block(doc):
    """The optional `tenants` block (PR 8 schema): the QoS policy, the
    weighted-mixture and noisy-neighbor scenarios, and the scheduling
    interleaving checksums. Carries the two hard QoS gates:

    * bit-parity — FIFO, weighted-fair and reversed-priority drains of the
      same request stream produce bit-identical checksums (scheduling must
      never fork the numerics);
    * isolation — the saturating tenant in the noisy-neighbor scenario is
      quota-shed while every light tenant keeps completing, with a p99 no
      worse than 10x its uncontended (weighted-scenario) tail plus 50 ms
      of shared-runner slack.
    """
    tenants = doc["tenants"]
    policy = tenants["policy"]
    assert policy, "tenants block without policy rows"
    for i, cls in enumerate(policy):
        assert cls["tenant"] == i, f"policy row {i} has tenant {cls['tenant']}"
        assert cls["name"], f"policy row {i} has an empty name"
        assert cls["weight"] >= 1, (i, cls["weight"])
        assert cls["quota"] is None or cls["quota"] >= 0, (i, cls["quota"])
    scenarios = tenants["scenarios"]
    weighted, noisy = scenarios["weighted"], scenarios["noisy"]
    validate_tenant_scenario(weighted, policy, "weighted")
    validate_tenant_scenario(noisy, policy, "noisy")
    # Hard gate 1: scheduling interleavings are bit-identical. The floats
    # round-trip bit-exactly through JSON (shortest-round-trip printing),
    # so equality here is the Rust-side to_bits comparison.
    inter = tenants["interleaving"]
    assert inter["requests"] >= 1, inter["requests"]
    assert inter["match"] is True, \
        "scheduling interleavings diverged: the QoS layer forked the numerics"
    assert inter["fifo"] == inter["weighted"] == inter["reversed"], \
        f"interleaving checksums differ: fifo {inter['fifo']} / " \
        f"weighted {inter['weighted']} / reversed {inter['reversed']}"
    # Hard gate 2: noisy-neighbor isolation. The heavy tenant (row 0,
    # offered the whole request budget at 4x rate) must hit its quota;
    # every light tenant must keep completing with a bounded tail.
    heavy, lights = noisy["rows"][0], noisy["rows"][1:]
    assert lights, "noisy-neighbor scenario needs at least one light tenant"
    assert heavy["quota_shed"] > 0, \
        "the saturating tenant never hit its quota — the noisy-neighbor " \
        "scenario is not exercising admission control"
    for light, calm in zip(lights, weighted["rows"][1:]):
        t = light["tenant"]
        assert light["quota_shed"] == 0, \
            f"light tenant {t} was quota-shed: the heavy tenant's load " \
            f"leaked into its admission budget"
        assert light["completed_ok"] == light["offered"], \
            f"light tenant {t} completed {light['completed_ok']} of " \
            f"{light['offered']}: starved by the noisy neighbor"
        assert calm["latency_ns"]["p99"] is not None, \
            f"light tenant {t} has no uncontended tail to compare against"
        bound = calm["latency_ns"]["p99"] * 10.0 + 5e7
        assert light["latency_ns"]["p99"] <= bound, \
            f"light tenant {t} p99 {light['latency_ns']['p99']:.0f} ns " \
            f"exceeds 10x its uncontended tail + 50 ms ({bound:.0f} ns): " \
            f"weighted-fair scheduling failed to isolate it"


def validate_serving(doc, smoke_async_check=False):
    assert doc["subsystem"] == "serve"
    assert doc["backend"] == "native-mt"
    assert doc["threads"] >= 1
    requests = doc["requests"]
    assert requests >= 1
    assert doc["batch"] >= 1 and doc["batches"] >= 1
    assert doc["fused"] + doc["sharded"] == requests, \
        f"fused {doc['fused']} + sharded {doc['sharded']} != {requests}"
    kernel = doc["kernel"]
    if doc["compensated"]:
        assert kernel.startswith("kahan_dot."), kernel
        flops_per_update = 5
    else:
        assert kernel.startswith("naive_dot."), kernel
        flops_per_update = 2
    assert doc["flops"] == doc["updates"] * flops_per_update, \
        "flop accounting does not match the served kernel class"
    lat = doc["latency_ns"]
    validate_latency_block(lat)
    assert doc["mflops"] > 0 and doc["gups"] > 0 and doc["reqs_per_s"] > 0
    assert doc["busy_ns"] > 0 and doc["elapsed_ns"] >= doc["busy_ns"] * 0.99
    assert doc["threshold_source"] in ("model", "override", "calibrated")
    threshold = doc["shard_threshold"]
    validate_crossover_value(threshold)
    assert doc["mode"] in ("closed", "open")
    if doc["mode"] == "open":
        assert doc["rate_rps"] > 0
    else:
        assert doc["rate_rps"] is None
    mix = doc["mix"]
    assert mix, "empty request mixture"
    for e in mix:
        assert e["n"] >= 1 and e["weight"] > 0, e
    # When the mixture straddles an explicit finite threshold and the run
    # is big enough, both scheduling paths must carry traffic.
    if threshold is not None and requests >= 64:
        sizes = [e["n"] for e in mix]
        if min(sizes) < threshold <= max(sizes):
            assert doc["fused"] > 0, "mixture straddles threshold but nothing fused"
            assert doc["sharded"] > 0, "mixture straddles threshold but nothing sharded"
    # Queue-mode block: side-by-side sync/async open-loop rows through the
    # bounded submission queue (PR 5 schema).
    queue = doc["queue"]
    assert queue["depth"] >= 1 and queue["batch_max"] >= 1
    assert queue["batch_window_us"] >= 0
    open_loop = doc["open_loop"]
    assert open_loop["rate_rps"] > 0
    sync_row, async_row = open_loop["sync"], open_loop["async"]
    for row in (sync_row, async_row):
        validate_queue_row(row, requests)
        assert row["max_queue_depth"] <= queue["depth"], \
            "queue high-water exceeds the configured depth (backpressure bound)"
    # Bit-parity across paths: the submission-order checksums are equal,
    # and so is the traffic split (same request stream, same threshold).
    assert async_row["checksum"] == sync_row["checksum"] == doc["checksum"], \
        "async / sync / batch checksums differ: determinism contract broken"
    assert (async_row["fused"], async_row["sharded"]) == \
        (sync_row["fused"], sync_row["sharded"]) == (doc["fused"], doc["sharded"])
    # Optional wire block: the same open-loop stream replayed through a
    # loopback serve-net TCP server (PR 6 schema). The bench only emits it
    # when it owns the loopback server, so the checksum must match the
    # in-process rows bitwise — the determinism contract extends across
    # the socket (docs/PROTOCOL.md, docs/ARCHITECTURE.md).
    wire = doc.get("wire")
    if wire is not None:
        validate_wire_row(wire, requests)
        assert wire["max_queue_depth"] <= queue["depth"], \
            "wire queue high-water exceeds the configured depth"
        assert wire["checksum"] == doc["checksum"], \
            "wire / in-process checksums differ: determinism contract " \
            "broken across the socket"
        assert (wire["fused"], wire["sharded"]) == \
            (doc["fused"], doc["sharded"]), \
            "wire traffic split diverged from the in-process split"
    assert isinstance(doc["async_p99_ok"], bool)
    if smoke_async_check:
        assert wire is not None, \
            "--smoke-async-check requires the wire row (serve-bench must " \
            "run with --wire-connections >= 1)"
        # Hard overlap check, meant only for the CI smoke configuration.
        # The request stream and results are deterministic there, but the
        # latency columns are still real measurements on a shared runner,
        # so allow 10% relative plus ~one scheduler quantum (1.5 ms) of
        # absolute slack for a stray preemption landing in the tail; a
        # genuine loss of overlap costs far more than that at the smoke
        # load. The bench itself warns at any excess over sync p99.
        bound = sync_row["latency_ns"]["p99"] * 1.10 + 1.5e6
        assert async_row["latency_ns"]["p99"] <= bound, \
            "async p99 exceeds sync p99 at the same offered load " \
            f"({async_row['latency_ns']['p99']:.0f} vs {sync_row['latency_ns']['p99']:.0f} ns)"
    if doc["threshold_source"] == "calibrated":
        assert "calibration" in doc, "calibrated threshold without a calibration block"
    if "calibration" in doc:
        validate_calibration(doc["calibration"])
    chaos = doc.get("chaos")
    if chaos is not None:
        validate_chaos_block(chaos)
    tenants = doc.get("tenants")
    if tenants is not None:
        validate_tenants_block(doc)
    zipf = doc.get("zipf")
    if zipf is not None:
        validate_zipf_block(zipf)
    integrity = doc.get("integrity")
    if integrity is not None:
        validate_integrity_block(integrity)
    extra = ", calibrated" if "calibration" in doc else ""
    if chaos is not None:
        extra += (f", chaos {chaos['total_injected']} faults / "
                  f"{chaos['hung_requests']} hung")
    if tenants is not None:
        heavy = tenants["scenarios"]["noisy"]["rows"][0]
        extra += (f", {len(tenants['policy'])} tenants "
                  f"(noisy heavy shed {heavy['quota_shed']})")
    if wire is not None:
        extra += (f", wire p99 {wire['latency_ns']['p99'] / 1e3:.1f} us "
                  f"over {wire['connections']} conn")
    if zipf is not None:
        extra += (f", zipf {zipf['speedup']:.1f}x "
                  f"({zipf['cache']['hits']} cache hits, bit-exact)")
    if integrity is not None:
        extra += (f", integrity {integrity['total_detected']}/"
                  f"{integrity['total_injected']} detected / "
                  f"{integrity['delivered_corrupt']} delivered corrupt")
    return f"{requests} requests ({doc['fused']} fused / {doc['sharded']} sharded), " \
           f"{doc['mode']} loop, p99 {lat['p99'] / 1e3:.1f} us, " \
           f"{doc['mflops']:.0f} MFlop/s; queue async p99 " \
           f"{async_row['latency_ns']['p99'] / 1e3:.1f} us vs sync " \
           f"{sync_row['latency_ns']['p99'] / 1e3:.1f} us{extra}"


def validate_summary(doc):
    assert doc["schema"] == "kahan-ecm-bench-summary/v1"
    docs = doc["documents"]
    assert docs, "summary contains no documents"
    for kind, sub in docs.items():
        assert kind_of(sub) == kind, f"summary entry '{kind}' has the wrong shape"
        VALIDATORS[kind](sub)
    assert isinstance(doc["headline"], dict)
    return f"{len(docs)} embedded documents: {', '.join(sorted(docs))}"


VALIDATORS = {
    "native": validate_native,
    "scaling": validate_scaling,
    "serving": validate_serving,
    "summary": validate_summary,
}


def headline_of(documents):
    """Extract the per-run perf-trajectory headline from validated docs."""
    h = {}
    native = documents.get("native")
    if native:
        kahan = [r["mflops"] for r in native["results"]
                 if r["kernel"].startswith("kahan_dot.")]
        h["native_best_kahan_dot_mflops"] = max(kahan)
        h["native_best_mflops"] = max(r["mflops"] for r in native["results"])
    scaling = documents.get("scaling")
    if scaling:
        h["scaling_threads_max"] = scaling["threads_max"]
        for curve in scaling["scaling"]:
            if curve["kernel"] == "kahan_dot.simd":
                h["scaling_kahan_dot_simd_peak_mflops"] = \
                    max(p["mflops"] for p in curve["points"])
    serving = documents.get("serving")
    if serving:
        h["serving_reqs_per_s"] = serving["reqs_per_s"]
        h["serving_p99_us"] = serving["latency_ns"]["p99"] / 1e3
        h["serving_mflops"] = serving["mflops"]
        h["serving_fused"] = serving["fused"]
        h["serving_sharded"] = serving["sharded"]
        open_loop = serving.get("open_loop")
        if open_loop:
            h["serving_async_p99_us"] = open_loop["async"]["latency_ns"]["p99"] / 1e3
            h["serving_sync_p99_us"] = open_loop["sync"]["latency_ns"]["p99"] / 1e3
            h["serving_async_reqs_per_s"] = open_loop["async"]["reqs_per_s"]
        wire = serving.get("wire")
        if wire:
            h["serving_wire_p99_us"] = wire["latency_ns"]["p99"] / 1e3
            h["serving_wire_reqs_per_s"] = wire["reqs_per_s"]
        cal = serving.get("calibration")
        if cal:
            h["serving_measured_p1_mflops"] = cal["measured"]["p1_mflops"]
        chaos = serving.get("chaos")
        if chaos:
            # Robustness accounting only — tools/compare_bench.py keeps
            # serving_chaos_* out of its perf-verdict allowlist.
            h["serving_chaos_total_injected"] = chaos["total_injected"]
            h["serving_chaos_hung"] = chaos["hung_requests"]
        tenants = serving.get("tenants")
        if tenants:
            # Per-tenant tails from the uncontended weighted scenario; the
            # dynamic names are matched by prefix in tools/compare_bench.py.
            for row in tenants["scenarios"]["weighted"]["rows"]:
                p99 = row["latency_ns"]["p99"]
                if p99 is not None:
                    h[f"serving_tenant_{row['name']}_p99_us"] = p99 / 1e3
        zipf = serving.get("zipf")
        if zipf:
            # Loopback A/B ratio on a shared runner — recorded in the
            # trajectory, excluded from compare_bench.py's perf verdict.
            h["serving_zipf_speedup"] = zipf["speedup"]
            h["serving_zipf_cache_hits"] = zipf["cache"]["hits"]
        integrity = serving.get("integrity")
        if integrity:
            # Data-integrity accounting only — tools/compare_bench.py
            # keeps serving_integrity_* out of its perf-verdict allowlist.
            h["serving_integrity_total_injected"] = integrity["total_injected"]
            h["serving_integrity_total_detected"] = integrity["total_detected"]
            h["serving_integrity_delivered_corrupt"] = \
                integrity["delivered_corrupt"]
    return h


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("files", nargs="+", help="BENCH_*.json documents")
    ap.add_argument("--merge", metavar="OUT",
                    help="write a merged BENCH_summary.json to OUT")
    ap.add_argument("--expect-scaling-threads", type=int, default=None,
                    help="pin threads_max of the scaling document")
    ap.add_argument("--smoke-async-check", action="store_true",
                    help="hard-check async p99 <= sync p99 (deterministic "
                         "CI smoke configuration only)")
    args = ap.parse_args(argv)

    documents = {}
    for path in args.files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            fail(f"{path}: {e}")
        kind = kind_of(doc)
        try:
            if kind == "scaling":
                note = validate_scaling(doc, args.expect_scaling_threads)
            elif kind == "serving":
                note = validate_serving(doc, args.smoke_async_check)
            else:
                note = VALIDATORS[kind](doc)
        except AssertionError as e:
            fail(f"{path} ({kind}): {e}")
        if kind in documents:
            fail(f"{path}: duplicate document kind '{kind}'")
        documents[kind] = doc
        print(f"OK {kind:8s} {path}: {note}")

    if args.merge:
        if "summary" in documents:
            fail("--merge input must be the raw documents, not a summary")
        summary = {
            "schema": "kahan-ecm-bench-summary/v1",
            "headline": headline_of(documents),
            "documents": documents,
        }
        validate_summary(summary)
        with open(args.merge, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"OK summary  {args.merge}: "
              f"{len(documents)} documents, {len(summary['headline'])} headline metrics")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
